//! Adaptive space-time controller: online (lanes, pipeline depth)
//! reconfiguration from observed load.
//!
//! The paper's core claim is a *dynamic* space-time scheduler — its wins
//! come from adapting the space/time split to the offered load — yet after
//! the spatial-lane and pipelining PRs our `lanes` and `pipeline_depth`
//! were frozen at config-load time: a diurnal or bursty tenant mix ran the
//! whole day at whatever split the operator guessed. D-STACK
//! (arXiv:2304.13541) and DARIS (arXiv:2504.08795) both show the GPU
//! partition must be chosen per-workload from a demand model to reach the
//! knee of the throughput curve. This module closes that loop with a
//! per-device-shard feedback controller that every `dwell_rounds`
//! scheduling rounds re-decides the resident lane count and effective
//! pipeline depth.
//!
//! ## Signals → decision
//!
//! ```text
//!   QueueSet ──────── backlog, arrival-rate EWMA ────────┐
//!   CostModel ─────── per-lane-count interference        │
//!                     stretch (lane_stretch /            ├─► utility
//!                     lane_calibration)                  │   argmax over
//!   driver/replay ─── launches+requests per round,       │   (lanes, depth)
//!                     mean launch duration, plan time    │   + hysteresis
//!   SloMonitor ────── windowed deadline attainment ──────┘   + pressure
//! ```
//!
//! The utility model prices a candidate `(n, d)` (lanes, depth) round:
//!
//! * effective lanes `e = min(n, launches_per_round)` — a plan never spans
//!   more lanes than it has launches (`RoundPlan::lanes_used`),
//! * round makespan `M(n) = ceil(L / e) * mean_launch_s * stretch(e)` —
//!   launches execute in `ceil(L/e)` waves, each stretched by the
//!   calibrated co-location interference term at `e` resident lanes,
//! * round cadence `C(n, d) = plan_s + M(n)` serially (`d = 1`), or
//!   `max(plan_s, M(n))` pipelined (`d >= 2`, planning hidden behind
//!   execution — the fig11 mechanism),
//! * predicted throughput `T = requests_per_round / C`,
//! * predicted worst-case request latency `(d - 1) * C + M(n)` (pipeline
//!   residency plus own round) — a candidate is **feasible** iff that fits
//!   the tightest SLO among the shard's tenants.
//!
//! The controller picks the max-throughput feasible candidate (ties prefer
//! fewer lanes, then shallower depth: less interference and less pipeline
//! residency at equal predicted throughput); if nothing is feasible it
//! picks the minimum-latency candidate — the least-bad degradation.
//!
//! ## Hysteresis and pressure valves
//!
//! Decisions are made at most once per `dwell_rounds` window, and a
//! model-driven switch additionally requires a relative predicted-utility
//! gain of at least `improvement` — together these stop the controller
//! from flapping on EWMA noise (the property tests pin both bounds). Two
//! pressure valves override the pure model, because the model can be
//! *stale*: the stretch EWMA is only re-learned at lane counts that
//! actually run (no launches → no observations → no recovery, the same
//! trap as the admission-probe and solo-probe valves elsewhere):
//!
//! * **backlog pressure** — the backlog exceeds two rounds' worth of
//!   drain and is not relieving (still growing, or the offered-load EWMA
//!   exceeds the current point's predicted throughput — a sawtooth
//!   backlog must not hide genuine overload), yet the model sees no
//!   better candidate:
//!   escalate anyway, straight to the wave-optimal lane count
//!   `ceil(launches_per_round)` (the best case if interference were mild —
//!   one wave per round). If the model was stale-pessimistic (the stretch
//!   was learned on a different class mix), the overlapped measurements at
//!   the explored count re-calibrate it within a few rounds and the model
//!   keeps it; if the model was right, the next window walks back, and an
//!   exploration backoff (one probe per two decision points) keeps the
//!   controller at the model's choice most of the time.
//! * **SLO pressure** — windowed deadline attainment fell below
//!   `slo_target` while the backlog is NOT growing (so the misses come
//!   from co-location stretch or pipeline residency, not under-capacity):
//!   step one lane down (or, already serial, one depth down).
//! * **steal imbalance** — with work-conserving lane execution on, a
//!   sustained steal rate (EWMA of the fraction of completions executed
//!   by a thief lane, fed via [`ControlSignals::steal_rate`]) means the
//!   balancer's predicted placement and run-time reality disagree: work
//!   keeps migrating at execution time. Occasional stealing is the
//!   mechanism working as designed, so a single spiky window does
//!   nothing; past [`STEAL_IMBALANCE`] the valve waives the
//!   `improvement` hysteresis for a model-driven switch — any candidate
//!   the model scores strictly better is taken, because the current
//!   point is demonstrably mispriced. Inert (EWMA pinned at 0) for
//!   non-stealing configs.
//!
//! With `adaptive = false` the driver never constructs a controller and
//! the static `lanes` / `pipeline_depth` paths are executed unchanged.

use std::collections::HashMap;

/// Bounds and hysteresis knobs (the validated `[controller]` config
/// section resolves into this — see [`crate::config::ControllerConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct ControllerParams {
    /// Candidate lane counts are `1..=max_lanes`.
    pub max_lanes: usize,
    /// Candidate pipeline depths are `1..=max_depth`.
    pub max_depth: usize,
    /// Rounds between decision points; also the minimum dwell between two
    /// reconfigurations (the controller changes at most once per window).
    pub dwell_rounds: u32,
    /// Relative predicted-throughput gain a model-driven switch must show
    /// (0.05 == 5%); pressure-valve moves are exempt.
    pub improvement: f64,
    /// Windowed deadline-attainment target that arms the SLO pressure
    /// valve when undershot.
    pub slo_target: f64,
}

impl ControllerParams {
    fn clamp_lanes(&self, lanes: usize) -> usize {
        lanes.clamp(1, self.max_lanes.max(1))
    }

    fn clamp_depth(&self, depth: usize) -> usize {
        depth.clamp(1, self.max_depth.max(1))
    }
}

/// A (resident lanes, pipeline depth) operating point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub lanes: usize,
    pub depth: usize,
}

/// One decision window's observed inputs. All durations in seconds; a
/// signal a caller cannot provide stays at its neutral value (`0.0` /
/// `None`), and a window without launch data (`mean_launch_s == 0`) keeps
/// the current decision — there is nothing to model.
#[derive(Debug, Clone, Default)]
pub struct ControlSignals {
    /// Requests pending admission on this shard right now.
    pub backlog: usize,
    /// Offered-load EWMA from the admission front
    /// ([`crate::coordinator::queue::QueueSet::arrival_rate`]), req/s.
    /// Second trigger of the backlog valve: a deep backlog counts as
    /// pressure while it keeps growing OR while the offered rate exceeds
    /// the current operating point's predicted throughput (a sawtooth
    /// backlog that momentarily shrinks must not hide a genuine
    /// overload). `0.0` (hosts without an estimator) degrades to the
    /// growth-only trigger.
    pub arrival_rate: f64,
    /// EWMA launches per non-empty round.
    pub launches_per_round: f64,
    /// EWMA requests drained per non-empty round.
    pub requests_per_round: f64,
    /// EWMA *solo-equivalent* launch duration (overlapped measurements
    /// deflated by their round's stretch before feeding this).
    pub mean_launch_s: f64,
    /// EWMA driver-side plan + marshal time per round.
    pub plan_s: f64,
    /// Interference stretch by resident lane count: `stretch[n]` prices a
    /// launch co-resident with `n - 1` others. Index 0 unused; missing
    /// counts are priced at the last known entry.
    pub stretch: Vec<f64>,
    /// Windowed deadline attainment since the previous decision (None
    /// before any verdict this window).
    pub slo_attainment: Option<f64>,
    /// Tightest SLO among the shard's servable tenants, seconds
    /// (`<= 0` == no deadline constraint; every candidate is feasible).
    pub min_slo_s: f64,
    /// Fraction of this window's completions that executed on a thief
    /// lane (work-conserving execution; `0.0` with stealing off or for
    /// hosts without a stealing pool — the imbalance valve stays inert).
    pub steal_rate: f64,
}

/// Per-decision-window blend of the steal-rate EWMA. At `0.3`, one heavy
/// window from a cold EWMA stays under [`STEAL_IMBALANCE`] (0.3 · 0.8 =
/// 0.24) but a second consecutive one crosses it — "sustained" is at
/// least two windows by construction.
const STEAL_ALPHA: f64 = 0.3;

/// Steal-rate EWMA above which the imbalance valve arms (see the module
/// docs): a quarter of completions migrating at execution time, window
/// after window, is no longer opportunistic smoothing — the operating
/// point is mispriced.
const STEAL_IMBALANCE: f64 = 0.25;

impl ControlSignals {
    fn stretch_at(&self, lanes: usize) -> f64 {
        if lanes <= 1 {
            return 1.0;
        }
        self.stretch
            .get(lanes)
            .or_else(|| self.stretch.last())
            .copied()
            .unwrap_or(1.0)
            .max(1.0)
    }
}

/// A scored candidate operating point.
#[derive(Debug, Clone, Copy)]
struct Candidate {
    decision: Decision,
    throughput: f64,
    latency_s: f64,
    feasible: bool,
}

/// The per-shard feedback controller. Pure over its inputs: every decision
/// is a function of the [`ControlSignals`] handed to `observe_round` at a
/// dwell boundary plus the controller's own prior decision — no clocks, no
/// randomness — so the same logic drives the real driver, the gpusim
/// policy ([`crate::gpusim::Policy::SpaceTimeAdaptive`]), and the fig12
/// trace replay, and property tests can replay arbitrary signal sequences.
#[derive(Debug)]
pub struct AdaptiveController {
    params: ControllerParams,
    current: Decision,
    rounds_since_eval: u32,
    prev_backlog: usize,
    /// Decision points evaluated (dwell boundaries with usable signals).
    evals: u64,
    /// Eval index of the last backlog-pressure exploration (0 == never);
    /// probes are rate-limited to one per two decision points.
    last_explore_eval: u64,
    /// Times the decision actually changed.
    reconfigs: u64,
    /// Steal-rate EWMA across decision windows (imbalance valve input).
    steal_ewma: f64,
    /// Predicted throughput of the chosen decision at the last eval.
    last_utility: f64,
    /// Best predicted throughput per candidate lane count at the last
    /// eval, ascending lane count (status JSON / serve table export).
    last_utilities: Vec<(usize, f64)>,
}

impl AdaptiveController {
    pub fn new(params: ControllerParams, initial: Decision) -> Self {
        let current = Decision {
            lanes: params.clamp_lanes(initial.lanes),
            depth: params.clamp_depth(initial.depth),
        };
        Self {
            params,
            current,
            rounds_since_eval: 0,
            prev_backlog: 0,
            evals: 0,
            last_explore_eval: 0,
            reconfigs: 0,
            steal_ewma: 0.0,
            last_utility: 0.0,
            last_utilities: Vec::new(),
        }
    }

    pub fn params(&self) -> &ControllerParams {
        &self.params
    }

    pub fn decision(&self) -> Decision {
        self.current
    }

    pub fn evals(&self) -> u64 {
        self.evals
    }

    pub fn reconfigs(&self) -> u64 {
        self.reconfigs
    }

    pub fn last_utility(&self) -> f64 {
        self.last_utility
    }

    pub fn last_utilities(&self) -> &[(usize, f64)] {
        &self.last_utilities
    }

    /// Current steal-rate EWMA (0.0 unless the host feeds
    /// [`ControlSignals::steal_rate`] from a stealing lane pool).
    pub fn steal_ewma(&self) -> f64 {
        self.steal_ewma
    }

    /// Score one candidate under the signals (see the module docs for the
    /// model). `requests_per_round` and `mean_launch_s` are pre-floored by
    /// the caller.
    fn score(&self, s: &ControlSignals, lanes: usize, depth: usize) -> Candidate {
        let launches = s.launches_per_round.max(1.0);
        // A plan never spans more lanes than it has launches: price the
        // candidate at its EFFECTIVE lane count so n > L ties with n == L
        // instead of borrowing an unobserved (usually optimistic) stretch.
        let eff = lanes.min(launches.ceil() as usize).max(1);
        // Fractional waves (floored at one): the launches-per-round EWMA
        // is an average, and rounding 1.1 launches up to a 2-wave serial
        // round would make 2 lanes look like a 2x win on a workload that
        // almost never has anything to overlap.
        let waves = (launches / eff as f64).max(1.0);
        let makespan = waves * s.mean_launch_s * s.stretch_at(eff);
        let cadence = if depth <= 1 {
            s.plan_s + makespan
        } else {
            s.plan_s.max(makespan)
        };
        let throughput = s.requests_per_round.max(1.0) / cadence.max(1e-12);
        let latency_s = (depth as f64 - 1.0) * cadence + makespan;
        let feasible = s.min_slo_s <= 0.0 || latency_s <= s.min_slo_s;
        Candidate { decision: Decision { lanes, depth }, throughput, latency_s, feasible }
    }

    /// Account one scheduling round; returns true when a dwell window just
    /// elapsed and the caller should gather [`ControlSignals`] and call
    /// [`AdaptiveController::decide`]. Splitting the cadence from the
    /// evaluation keeps signal gathering (which may lock a cost model) off
    /// the per-round path.
    pub fn tick(&mut self) -> bool {
        self.rounds_since_eval += 1;
        if self.rounds_since_eval < self.params.dwell_rounds.max(1) {
            return false;
        }
        self.rounds_since_eval = 0;
        true
    }

    /// Account one scheduling round; at each `dwell_rounds` boundary,
    /// re-evaluate and possibly (at most once per window) change the
    /// decision. Returns the current decision either way.
    pub fn observe_round(&mut self, signals: &ControlSignals) -> Decision {
        if self.tick() {
            self.decide(signals)
        } else {
            self.current
        }
    }

    /// One decision point: re-evaluate the candidate grid under `signals`
    /// and possibly change the decision. Hosts must call this only when
    /// [`AdaptiveController::tick`] returns true (or use
    /// [`AdaptiveController::observe_round`], which enforces the cadence)
    /// — the dwell/hysteresis guarantees are per decision point.
    pub fn decide(&mut self, signals: &ControlSignals) -> Decision {
        if signals.mean_launch_s <= 0.0 || signals.requests_per_round <= 0.0 {
            // No launch data this window: nothing to model, hold steady.
            return self.current;
        }
        self.evals += 1;
        self.steal_ewma = STEAL_ALPHA * signals.steal_rate.clamp(0.0, 1.0)
            + (1.0 - STEAL_ALPHA) * self.steal_ewma;

        // Score the whole candidate grid; remember the per-lane-count best
        // for the status export.
        let mut best: Option<Candidate> = None;
        let mut current_score = self.score(signals, self.current.lanes, self.current.depth);
        self.last_utilities.clear();
        for lanes in 1..=self.params.max_lanes.max(1) {
            let mut lane_best = f64::NEG_INFINITY;
            for depth in 1..=self.params.max_depth.max(1) {
                let c = self.score(signals, lanes, depth);
                lane_best = lane_best.max(c.throughput);
                if c.decision == self.current {
                    current_score = c;
                }
                let better = match &best {
                    None => true,
                    Some(b) => {
                        // Feasible beats infeasible; then max throughput;
                        // ties prefer fewer lanes, then shallower depth
                        // (strict inequality keeps the earlier — smaller —
                        // candidate on ties). Among infeasible candidates,
                        // min latency.
                        if c.feasible != b.feasible {
                            c.feasible
                        } else if c.feasible {
                            c.throughput > b.throughput * (1.0 + 1e-9)
                        } else {
                            c.latency_s < b.latency_s * (1.0 - 1e-9)
                        }
                    }
                };
                if better {
                    best = Some(c);
                }
            }
            self.last_utilities.push((lanes, lane_best));
        }
        let best = best.expect("candidate grid is non-empty");

        let pressure_floor = 2.0 * signals.requests_per_round.max(1.0);
        let backlog_pressure = signals.backlog as f64 > pressure_floor
            && (signals.backlog >= self.prev_backlog
                || signals.arrival_rate > current_score.throughput);
        let slo_pressure = signals
            .slo_attainment
            .is_some_and(|a| a < self.params.slo_target);
        // Sustained stealing: the current point is mispriced (see the
        // module docs' imbalance valve) — waive the improvement bar for a
        // model-driven switch below.
        let steal_pressure = self.steal_ewma > STEAL_IMBALANCE;
        self.prev_backlog = signals.backlog;

        let mut next = self.current;
        if slo_pressure && !backlog_pressure {
            // Misses without a growing backlog: co-location stretch or
            // pipeline residency is blowing deadlines the model thought
            // feasible. Shed interference first, then pipeline residency.
            if self.current.lanes > 1 {
                next.lanes = self.current.lanes - 1;
            } else if self.current.depth > 1 {
                next.depth = self.current.depth - 1;
            }
        } else if best.decision != self.current
            && (best.throughput > current_score.throughput * (1.0 + self.params.improvement)
                || (!current_score.feasible && best.feasible)
                || (backlog_pressure && best.throughput > current_score.throughput)
                || (steal_pressure && best.throughput > current_score.throughput))
        {
            next = best.decision;
        } else if backlog_pressure
            && self.current.lanes < self.params.max_lanes
            && (self.last_explore_eval == 0 || self.evals >= self.last_explore_eval + 2)
        {
            // Sustained backlog but the model sees nothing better: the
            // stretch may be stale (learned on another class mix). Probe
            // the wave-optimal lane count — the best candidate if
            // interference were mild — so the measurements at that count
            // either justify it or the next window walks back. Stepping
            // one lane at a time would strand the probe at local dips
            // (e.g. 3 lanes needs the same waves as 2 but stretches more).
            let wave_optimal = (signals.launches_per_round.max(1.0).ceil() as usize)
                .max(self.current.lanes + 1);
            next.lanes = wave_optimal;
            self.last_explore_eval = self.evals;
        }
        next.lanes = self.params.clamp_lanes(next.lanes);
        next.depth = self.params.clamp_depth(next.depth);

        self.last_utility = self.score(signals, next.lanes, next.depth).throughput;
        if next != self.current {
            self.current = next;
            self.reconfigs += 1;
        }
        self.current
    }
}

/// Rolling round-level signal estimators shared by every controller host
/// (driver, gpusim policy, fig12 replay): EWMAs of launches/requests per
/// non-empty round, solo-equivalent launch duration, driver-side plan
/// time, and — for hosts without a
/// [`CostModel`](crate::coordinator::costmodel::CostModel) — a measured
/// per-lane-count stretch table seeded by the caller.
#[derive(Debug)]
pub struct SignalTracker {
    alpha: f64,
    launches_pr: f64,
    requests_pr: f64,
    mean_launch_s: f64,
    plan_s: f64,
    rounds: u64,
    launch_obs: u64,
    plan_obs: u64,
    /// lane count -> measured stretch EWMA (hosts that feed
    /// [`SignalTracker::observe_stretch`]; the driver reads its cost
    /// model's calibrated table instead).
    stretch: HashMap<usize, (f64, u64)>,
}

impl Default for SignalTracker {
    fn default() -> Self {
        Self::new(0.2)
    }
}

impl SignalTracker {
    pub fn new(alpha: f64) -> Self {
        Self {
            alpha,
            launches_pr: 0.0,
            requests_pr: 0.0,
            mean_launch_s: 0.0,
            plan_s: 0.0,
            rounds: 0,
            launch_obs: 0,
            plan_obs: 0,
            stretch: HashMap::new(),
        }
    }

    fn blend(alpha: f64, seeded: bool, ewma: f64, sample: f64) -> f64 {
        if seeded {
            alpha * sample + (1.0 - alpha) * ewma
        } else {
            sample
        }
    }

    /// Account one non-empty round: how many launches it planned, how many
    /// requests it drained, and the driver-side plan/marshal seconds.
    pub fn observe_round(&mut self, launches: usize, drained: usize, plan_s: f64) {
        if launches == 0 {
            return;
        }
        let seeded = self.rounds > 0;
        self.launches_pr = Self::blend(self.alpha, seeded, self.launches_pr, launches as f64);
        self.requests_pr = Self::blend(self.alpha, seeded, self.requests_pr, drained as f64);
        self.rounds += 1;
        if plan_s.is_finite() && plan_s >= 0.0 {
            let seeded = self.plan_obs > 0;
            self.plan_s = Self::blend(self.alpha, seeded, self.plan_s, plan_s);
            self.plan_obs += 1;
        }
    }

    /// Account one measured launch duration, already deflated to its
    /// solo-equivalent (divide an overlapped measurement by its round's
    /// stretch before calling).
    pub fn observe_launch(&mut self, solo_s: f64) {
        if !solo_s.is_finite() || solo_s <= 0.0 {
            return;
        }
        let seeded = self.launch_obs > 0;
        self.mean_launch_s = Self::blend(self.alpha, seeded, self.mean_launch_s, solo_s);
        self.launch_obs += 1;
    }

    /// Account one measured co-location stretch (`measured / solo`) at
    /// `lanes` concurrently-resident lanes.
    pub fn observe_stretch(&mut self, lanes: usize, ratio: f64) {
        if lanes <= 1 || !ratio.is_finite() || ratio <= 0.0 {
            return;
        }
        let entry = self.stretch.entry(lanes).or_insert((0.0, 0));
        entry.0 = Self::blend(self.alpha, entry.1 > 0, entry.0, ratio.max(1.0));
        entry.1 += 1;
    }

    pub fn launches_per_round(&self) -> f64 {
        self.launches_pr
    }

    pub fn requests_per_round(&self) -> f64 {
        self.requests_pr
    }

    pub fn mean_launch_s(&self) -> f64 {
        self.mean_launch_s
    }

    pub fn plan_s(&self) -> f64 {
        self.plan_s
    }

    /// Stretch table `[_, 1.0, s2, .., s_max]` for [`ControlSignals`]:
    /// measured EWMAs where observed, else `seed(n)` (callers pass the
    /// device spec's analytic `lane_stretch`).
    pub fn stretch_table(&self, max_lanes: usize, seed: impl Fn(usize) -> f64) -> Vec<f64> {
        (0..=max_lanes.max(1))
            .map(|n| {
                if n <= 1 {
                    1.0
                } else {
                    match self.stretch.get(&n) {
                        Some(&(s, obs)) if obs > 0 => s.max(1.0),
                        _ => seed(n).max(1.0),
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(max_lanes: usize, max_depth: usize, dwell: u32) -> ControllerParams {
        ControllerParams {
            max_lanes,
            max_depth,
            dwell_rounds: dwell,
            improvement: 0.05,
            slo_target: 0.99,
        }
    }

    fn signals(
        launches: f64,
        requests: f64,
        dur: f64,
        stretch: Vec<f64>,
        slo: f64,
    ) -> ControlSignals {
        ControlSignals {
            backlog: 0,
            arrival_rate: 0.0,
            launches_per_round: launches,
            requests_per_round: requests,
            mean_launch_s: dur,
            plan_s: 0.0,
            stretch,
            slo_attainment: None,
            min_slo_s: slo,
            steal_rate: 0.0,
        }
    }

    /// Drive one decision (dwell boundary) out of the controller.
    fn decide(ctl: &mut AdaptiveController, s: &ControlSignals) -> Decision {
        let dwell = ctl.params().dwell_rounds;
        let mut d = ctl.decision();
        for _ in 0..dwell {
            d = ctl.observe_round(s);
        }
        d
    }

    #[test]
    fn single_launch_rounds_stay_serial() {
        // L == 1: nothing to overlap, more lanes only add stretch.
        let mut ctl =
            AdaptiveController::new(params(4, 1, 8), Decision { lanes: 1, depth: 1 });
        let s = signals(1.0, 1.0, 1e-3, vec![1.0, 1.0, 1.3, 1.6, 2.0], 0.0);
        for _ in 0..5 {
            assert_eq!(decide(&mut ctl, &s), Decision { lanes: 1, depth: 1 });
        }
        assert_eq!(ctl.reconfigs(), 0);
    }

    #[test]
    fn wide_rounds_with_mild_interference_scale_out() {
        // 4 launches per round at stretch(4) = 1.3: T(4) = 4/1.3 = 3.1x
        // the serial candidate — the controller must take it.
        let mut ctl =
            AdaptiveController::new(params(4, 1, 4), Decision { lanes: 1, depth: 1 });
        let s = signals(4.0, 16.0, 1e-3, vec![1.0, 1.0, 1.1, 1.2, 1.3], 0.0);
        assert_eq!(decide(&mut ctl, &s), Decision { lanes: 4, depth: 1 });
        assert_eq!(ctl.reconfigs(), 1);
        // Stationary signals: no further flapping.
        for _ in 0..5 {
            assert_eq!(decide(&mut ctl, &s), Decision { lanes: 4, depth: 1 });
        }
        assert_eq!(ctl.reconfigs(), 1);
        assert!(ctl.last_utility() > 0.0);
        assert_eq!(ctl.last_utilities().len(), 4);
    }

    #[test]
    fn brutal_interference_pulls_back_to_serial() {
        // stretch(n) >= n: overlap never pays; from a 4-lane start the
        // controller must walk back to 1.
        let mut ctl =
            AdaptiveController::new(params(4, 1, 4), Decision { lanes: 4, depth: 1 });
        let s = signals(4.0, 16.0, 1e-3, vec![1.0, 1.0, 2.2, 3.4, 4.8], 0.0);
        let mut last = ctl.decision();
        for _ in 0..6 {
            last = decide(&mut ctl, &s);
        }
        assert_eq!(last, Decision { lanes: 1, depth: 1 });
    }

    #[test]
    fn tight_slo_forbids_pipeline_residency() {
        // Loose SLO: depth 2 hides the plan time -> higher throughput.
        let loose = signals(2.0, 8.0, 1e-3, vec![1.0, 1.0, 1.1], 1.0);
        let mut s = ControlSignals { plan_s: 1e-3, ..loose };
        let mut ctl =
            AdaptiveController::new(params(2, 2, 4), Decision { lanes: 1, depth: 1 });
        assert_eq!(decide(&mut ctl, &s), Decision { lanes: 2, depth: 2 });
        // Tight SLO: (d-1)*cadence + M no longer fits -> depth 1.
        s.min_slo_s = 2.0e-3;
        let mut ctl =
            AdaptiveController::new(params(2, 2, 4), Decision { lanes: 1, depth: 1 });
        let d = decide(&mut ctl, &s);
        assert_eq!(d.depth, 1, "pipeline residency must respect the SLO");
    }

    #[test]
    fn backlog_pressure_explores_past_a_stale_model() {
        // The stretch table claims overlap never pays (learned on another
        // class mix), but the backlog keeps growing: the valve must probe
        // the wave-optimal lane count anyway; fresh (mild) measurements at
        // that count then let the model keep it.
        let mut ctl =
            AdaptiveController::new(params(4, 1, 4), Decision { lanes: 1, depth: 1 });
        let mut s = signals(4.0, 16.0, 1e-3, vec![1.0, 1.0, 2.2, 3.4, 4.8], 0.0);
        s.backlog = 1000;
        assert_eq!(decide(&mut ctl, &s).lanes, 4, "probe ceil(L) = 4 lanes");
        // Running at 4 lanes re-measured the stretch as mild: the model
        // now justifies the probe on its own and holds the point even
        // after the backlog clears.
        s.stretch = vec![1.0, 1.0, 1.1, 1.2, 1.3];
        s.backlog = 1200;
        assert_eq!(decide(&mut ctl, &s).lanes, 4);
        s.backlog = 0;
        assert_eq!(decide(&mut ctl, &s).lanes, 4);
        assert_eq!(ctl.reconfigs(), 1, "one probe, no flapping");
    }

    #[test]
    fn offered_load_above_capacity_pressures_even_a_shrinking_backlog() {
        // A sawtooth backlog momentarily shrinks while the offered-load
        // EWMA still exceeds the current point's predicted throughput:
        // the arrival-rate disjunct must keep the valve armed. The
        // improvement threshold is set high so only the valve can move.
        let mut ctl = AdaptiveController::new(
            ControllerParams {
                max_lanes: 4,
                max_depth: 1,
                dwell_rounds: 4,
                improvement: 0.5,
                slo_target: 0.99,
            },
            Decision { lanes: 4, depth: 1 },
        );
        let mut s = signals(4.0, 16.0, 1e-3, vec![1.0, 1.0, 2.2, 3.4, 4.8], 0.0);
        // Window 1: deep growing backlog -> pressure switches to the
        // model's better candidate (serial, under this brutal stretch).
        s.backlog = 5000;
        assert_eq!(decide(&mut ctl, &s).lanes, 1);
        // Window 2: backlog shrinking, no offered-load signal: no
        // pressure, the model holds.
        s.backlog = 4000;
        assert_eq!(decide(&mut ctl, &s).lanes, 1);
        // Window 3: still shrinking, but the offered rate exceeds the
        // serial candidate's predicted throughput (~4000 req/s): the
        // valve re-arms and probes the wave-optimal count.
        s.backlog = 3000;
        s.arrival_rate = 50_000.0;
        assert_eq!(decide(&mut ctl, &s).lanes, 4, "rate trigger must probe");
    }

    #[test]
    fn slo_pressure_sheds_interference_first_then_depth() {
        let mut ctl =
            AdaptiveController::new(params(4, 2, 4), Decision { lanes: 3, depth: 2 });
        let mut s = signals(4.0, 16.0, 1e-3, vec![1.0, 1.0, 1.1, 1.2, 1.3], 0.0);
        s.slo_attainment = Some(0.5);
        assert_eq!(decide(&mut ctl, &s), Decision { lanes: 2, depth: 2 });
        assert_eq!(decide(&mut ctl, &s), Decision { lanes: 1, depth: 2 });
        assert_eq!(decide(&mut ctl, &s), Decision { lanes: 1, depth: 1 });
        // Fully shed: nothing left to step down; holds.
        assert_eq!(decide(&mut ctl, &s), Decision { lanes: 1, depth: 1 });
    }

    #[test]
    fn sustained_stealing_waives_the_switch_hysteresis() {
        // Best candidate (4 lanes, ~1.33x) sits UNDER the 1.5x improvement
        // bar: without steal pressure the controller holds serial.
        let mut ctl = AdaptiveController::new(
            ControllerParams {
                max_lanes: 4,
                max_depth: 1,
                dwell_rounds: 4,
                improvement: 0.5,
                slo_target: 0.99,
            },
            Decision { lanes: 1, depth: 1 },
        );
        let mut s = signals(4.0, 16.0, 1e-3, vec![1.0, 1.0, 2.0, 2.5, 3.0], 0.0);
        assert_eq!(decide(&mut ctl, &s).lanes, 1, "under the improvement bar");
        // One heavy steal window from a cold EWMA is not "sustained".
        s.steal_rate = 0.8;
        assert_eq!(decide(&mut ctl, &s).lanes, 1, "one spike must not move it");
        assert!(ctl.steal_ewma() > 0.0);
        // The second consecutive heavy window crosses STEAL_IMBALANCE:
        // placement and reality disagree, so the merely-better candidate
        // is taken despite the hysteresis.
        assert_eq!(decide(&mut ctl, &s).lanes, 4, "sustained stealing switches");
        assert_eq!(ctl.reconfigs(), 1);
        // Once rebalanced the rate collapses and the new point holds.
        s.steal_rate = 0.0;
        for _ in 0..3 {
            assert_eq!(decide(&mut ctl, &s).lanes, 4);
        }
        assert_eq!(ctl.reconfigs(), 1, "no flapping after the switch");
    }

    #[test]
    fn no_signal_window_holds_the_decision() {
        let mut ctl =
            AdaptiveController::new(params(4, 2, 4), Decision { lanes: 2, depth: 2 });
        let s = ControlSignals::default();
        for _ in 0..4 {
            assert_eq!(decide(&mut ctl, &s), Decision { lanes: 2, depth: 2 });
        }
        assert_eq!(ctl.evals(), 0, "empty windows are not decision points");
    }

    #[test]
    fn initial_decision_clamped_to_bounds() {
        let ctl =
            AdaptiveController::new(params(2, 1, 4), Decision { lanes: 9, depth: 7 });
        assert_eq!(ctl.decision(), Decision { lanes: 2, depth: 1 });
    }

    #[test]
    fn prop_dwell_and_bounds_hold_under_arbitrary_signals() {
        // The ISSUE's controller property: over random signal sequences,
        // (a) the decision never changes more than once per dwell window,
        // (b) it always stays within [1, max_lanes] x [1, max_depth].
        use crate::util::prop::run_prop;
        run_prop("controller dwell + bounds", 0xAD17, 64, |rng| {
            let max_lanes = 1 + rng.gen_range(8) as usize;
            let max_depth = 1 + rng.gen_range(4) as usize;
            let dwell = 1 + rng.gen_range(6) as u32;
            let mut ctl = AdaptiveController::new(
                ControllerParams {
                    max_lanes,
                    max_depth,
                    dwell_rounds: dwell,
                    improvement: rng.gen_range(20) as f64 / 100.0,
                    slo_target: 0.9,
                },
                Decision {
                    lanes: 1 + rng.gen_range(12) as usize,
                    depth: 1 + rng.gen_range(6) as usize,
                },
            );
            let mut last = ctl.decision();
            let mut changes_this_window = 0u32;
            let mut round_in_window = 0u32;
            for _ in 0..200 {
                let stretch: Vec<f64> = (0..=max_lanes)
                    .map(|n| 1.0 + n as f64 * rng.gen_range(200) as f64 / 100.0)
                    .collect();
                let s = ControlSignals {
                    backlog: rng.gen_range(2000) as usize,
                    arrival_rate: rng.gen_range(10_000) as f64,
                    launches_per_round: rng.gen_range(12) as f64,
                    requests_per_round: rng.gen_range(64) as f64,
                    mean_launch_s: rng.gen_range(1000) as f64 * 1e-5,
                    plan_s: rng.gen_range(100) as f64 * 1e-5,
                    stretch,
                    slo_attainment: if rng.gen_bool(0.5) {
                        Some(rng.gen_range(100) as f64 / 100.0)
                    } else {
                        None
                    },
                    min_slo_s: rng.gen_range(100) as f64 * 1e-3,
                    steal_rate: rng.gen_range(100) as f64 / 100.0,
                };
                let d = ctl.observe_round(&s);
                assert!((1..=max_lanes).contains(&d.lanes), "lanes {d:?}");
                assert!((1..=max_depth).contains(&d.depth), "depth {d:?}");
                round_in_window += 1;
                if d != last {
                    changes_this_window += 1;
                    last = d;
                }
                if round_in_window == dwell {
                    assert!(
                        changes_this_window <= 1,
                        "{changes_this_window} changes within one dwell window"
                    );
                    round_in_window = 0;
                    changes_this_window = 0;
                }
            }
        });
    }

    #[test]
    fn tracker_ewmas_seed_from_first_sample() {
        let mut t = SignalTracker::default();
        t.observe_round(4, 16, 2e-4);
        assert_eq!(t.launches_per_round(), 4.0);
        assert_eq!(t.requests_per_round(), 16.0);
        assert_eq!(t.plan_s(), 2e-4);
        t.observe_launch(1e-3);
        assert_eq!(t.mean_launch_s(), 1e-3);
        // Empty rounds and garbage are inert.
        t.observe_round(0, 0, 1.0);
        t.observe_launch(f64::NAN);
        t.observe_launch(-1.0);
        assert_eq!(t.launches_per_round(), 4.0);
        assert_eq!(t.mean_launch_s(), 1e-3);
        // Blending moves toward new samples.
        t.observe_round(8, 32, 2e-4);
        assert!(t.launches_per_round() > 4.0 && t.launches_per_round() < 8.0);
    }

    #[test]
    fn tracker_stretch_table_blends_measured_over_seed() {
        let mut t = SignalTracker::default();
        let seed = |n: usize| 1.0 + 0.08 * (n as f64 - 1.0);
        let table = t.stretch_table(4, seed);
        assert_eq!(table.len(), 5);
        assert_eq!(table[1], 1.0);
        assert!((table[4] - 1.24).abs() < 1e-12, "unobserved counts seed");
        for _ in 0..50 {
            t.observe_stretch(2, 1.9);
        }
        t.observe_stretch(1, 9.0); // solo "stretch" is meaningless: ignored
        t.observe_stretch(3, f64::NAN);
        let table = t.stretch_table(4, seed);
        assert!((table[2] - 1.9).abs() < 0.05, "measured wins: {}", table[2]);
        assert!((table[3] - 1.16).abs() < 1e-12, "3 still seeded");
    }
}
