//! Persistent per-lane execution workers — the pipelined driver's data
//! plane.
//!
//! The pre-pipeline driver re-spawned a `thread::scope` of lane workers
//! every round: a thread spawn + join per device per round of pure
//! control-plane overhead, and the driver sat idle while launches
//! executed. This module replaces that with a **persistent worker pool**:
//! one worker thread per spatial lane, spawned once per device shard and
//! joined on shutdown, fed through per-lane SPSC work queues and drained
//! through one shared completion channel.
//!
//! The synchronization protocol itself (stealable deque dispatch, the
//! shared completion channel, resize grow/retire/drain, shutdown) lives in
//! [`crate::coordinator::protocol`] as [`LaneProtocol`], generic over a
//! [`crate::coordinator::protocol::SyncEnv`]; this module instantiates it
//! with real threads ([`StdEnv`]) and the production executor glue. The
//! same protocol code runs under the deterministic model checker
//! (`tests/modelcheck_protocol.rs`, `tests/modelcheck_steal.rs`), which
//! explores *every* interleaving of dispatch/collect/steal/resize/shutdown
//! — the tests below sample real-time schedules on top of that.
//!
//! **Work stealing** ([`LanePool::set_steal`], off by default) makes round
//! execution work-conserving: a lane whose queue drains early steals from
//! the back of the predicted-longest remaining lane instead of idling
//! until the round's slowest lane finishes — cost-model misprediction and
//! heavy-tailed launch costs stop translating directly into dead device
//! time. The steal victim is chosen by predicted-remaining cost, fed by
//! each item's [`WorkItem::cost_hint`] (the driver fills it from the cost
//! model's concurrent prediction). A stolen item keeps its **planned**
//! round/lane tags and additionally reports
//! [`Completion::executed_lane`]/[`Completion::stolen`], so cost-model
//! attribution (`observe_concurrent` keyed by the round's resident lane
//! count) stays correct while the driver's steal counters see where work
//! actually ran. The driver disables stealing around solo-calibration
//! probe rounds — probe measurements must stay un-overlapped.
//!
//! Every [`WorkItem`] is **round-tagged** at dispatch: it carries the
//! round id it was planned in and the lane count that round planned to
//! keep concurrently resident. The tag rides the [`Completion`] back, so
//! when rounds overlap in flight (pipeline depth > 1) every measurement
//! is still fed to the cost model with *its own round's* lane count —
//! never the lane count of whatever round happens to be dispatching when
//! the completion is processed.
//!
//! Ordering guarantees: each lane's queue is FIFO, so launches sharing a
//! lane execute in dispatch (urgency) order (with stealing on, a thief
//! takes the *least* urgent queued item — the back); across lanes
//! completions interleave by actual finish time. The pool is
//! execution-only — it never touches queues, the fusion cache, or the
//! cost model, so the driver thread can plan round N+1 (drain admission,
//! run the planner, marshal weights) while the pool executes round N.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::Launch;
use crate::coordinator::fusion_cache::WeightSet;
use crate::coordinator::protocol::{
    ItemRunner, LaneProtocol, LaneTagged, ProtoPayload, StdEnv,
};
use crate::coordinator::superkernel::{Flavor, LaunchResult, SuperKernelExec};
use crate::coordinator::tenant::ModelSpec;
use crate::runtime::PjrtEngine;

/// One launch handed to a lane worker, round-tagged and fully resolved:
/// the worker needs no registry, queue, or cache access to execute it
/// (weights were marshaled by the driver at dispatch time).
pub struct WorkItem {
    /// Round id this launch was planned in.
    pub round: u64,
    /// Launch index within its round's plan.
    pub index: usize,
    /// Spatial lane the launch executes on.
    pub lane: usize,
    /// Lanes the round planned to keep concurrently resident — the tag
    /// the cost model's interference term calibrates against.
    pub lanes_resident: usize,
    pub launch: Launch,
    /// Model spec of the launch's tenants (they share one shape class).
    pub spec: ModelSpec,
    /// Device-resident weight operands resolved by the driver (None for
    /// weight-less kinds, e.g. raw batched GEMM).
    pub weights: Option<Arc<WeightSet>>,
    /// Seconds the driver spent marshaling this launch's weights at
    /// dispatch time (cache miss: host gather + device upload). The
    /// worker folds it into the result's `marshal_s` so the cost model
    /// still observes the FULL launch cost even though the upload ran on
    /// the driver thread.
    pub weights_marshal_s: f64,
    /// Predicted execution cost (seconds, or any consistent unit) used by
    /// the steal-victim heuristic and resize re-homing. 0.0 degrades to
    /// unit cost (longest-queue victim selection).
    pub cost_hint: f64,
    /// Lane the item actually executed on (stamped by the protocol just
    /// before the runner; equals the planned `lane` unless stolen).
    pub executed_lane: usize,
    /// Whether the item was taken by a thief lane rather than its owner.
    pub stolen: bool,
    /// Execution attempt: 0 on first dispatch, 1 on the single
    /// failed-launch retry the driver routes through another lane.
    pub attempt: u32,
}

impl ProtoPayload for WorkItem {}

impl LaneTagged for WorkItem {
    fn lane(&self) -> usize {
        self.lane
    }
    fn set_lane(&mut self, lane: usize) {
        self.lane = lane;
    }
    fn cost(&self) -> f64 {
        if self.cost_hint > 0.0 {
            self.cost_hint
        } else {
            1.0
        }
    }
    fn set_executed(&mut self, lane: usize, stolen: bool) {
        self.executed_lane = lane;
        self.stolen = stolen;
    }
}

/// A finished launch, echoing its round tag so the driver attributes the
/// measurement, deadline verdicts, and lane accounting to the round that
/// planned it.
pub struct Completion {
    pub round: u64,
    pub index: usize,
    /// The PLANNED lane (post-clamp) — what cost-model attribution and the
    /// plan's lane accounting key on, even when the item was stolen.
    pub lane: usize,
    pub lanes_resident: usize,
    /// The lane that actually executed the item (differs from `lane` only
    /// when stolen, or after a resize re-home rewrote the plan).
    pub executed_lane: usize,
    /// Whether a thief lane executed the item.
    pub stolen: bool,
    /// Execution attempt this completion reports (0 = first, 1 = retry).
    pub attempt: u32,
    /// The launch rides back so entries can be scattered to responses
    /// without the driver holding the (already recycled) plan — and so a
    /// failed launch can be retried once on another lane without
    /// re-planning.
    pub launch: Launch,
    /// Spec/weights ride back for the same reason: the retry path rebuilds
    /// a WorkItem without touching the tenant registry or fusion cache.
    pub spec: ModelSpec,
    pub weights: Option<Arc<WeightSet>>,
    /// The original predicted cost, reused verbatim by the retry.
    pub cost_hint: f64,
    pub result: Result<LaunchResult>,
    /// Instant the launch finished on its worker.
    pub done: Instant,
}

impl ProtoPayload for Completion {}

/// What a lane worker runs per item. Production uses [`PjrtExecutor`];
/// tests and `benches/fig11_round_overhead.rs` substitute deterministic
/// synthetic executors so the pool/pipeline machinery is measurable and
/// testable without AOT artifacts.
pub trait LaunchExecutor: Send + Sync {
    fn execute(&self, item: &WorkItem) -> Result<LaunchResult>;
}

/// The production executor: one PJRT execution per item over the shared
/// engine (gather activations → execute → scatter; weights pre-resolved).
pub struct PjrtExecutor {
    engine: Arc<PjrtEngine>,
    flavor: Flavor,
}

impl PjrtExecutor {
    pub fn new(engine: Arc<PjrtEngine>, flavor: Flavor) -> Self {
        Self { engine, flavor }
    }
}

impl LaunchExecutor for PjrtExecutor {
    fn execute(&self, item: &WorkItem) -> Result<LaunchResult> {
        SuperKernelExec::new(&self.engine, self.flavor).execute_prepared(
            &item.launch,
            &item.spec,
            item.weights.as_deref(),
        )
    }
}

/// The protocol's per-item runner: execute with panic containment. A
/// panicking executor must not kill the worker — with the lane dead but
/// its siblings alive, the completion channel would stay open and the
/// driver would block forever on a round that can no longer drain. So
/// panics become per-item `Err` completions; the worker lives on.
struct ExecRunner {
    exec: Arc<dyn LaunchExecutor>,
}

impl ItemRunner<WorkItem, Completion> for ExecRunner {
    fn run(&self, item: WorkItem) -> Completion {
        let mut result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.exec.execute(&item)
        }))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            Err(anyhow!("lane executor panicked: {msg}"))
        });
        if let Ok(res) = &mut result {
            // Account the driver-side weight marshal so measurements
            // cover the whole launch cost.
            res.marshal_s += item.weights_marshal_s;
        }
        let done = Instant::now();
        let WorkItem {
            round,
            index,
            lane,
            lanes_resident,
            launch,
            spec,
            weights,
            cost_hint,
            executed_lane,
            stolen,
            attempt,
            ..
        } = item;
        Completion {
            round,
            index,
            lane,
            lanes_resident,
            executed_lane,
            stolen,
            attempt,
            launch,
            spec,
            weights,
            cost_hint,
            result,
            done,
        }
    }
}

/// The persistent pool: `lanes` worker threads, one SPSC queue each, one
/// shared completion channel. Spawned once; joined when dropped (or
/// explicitly via [`LanePool::shutdown`], which also hands back any
/// finished-but-uncollected completions so none are lost).
///
/// The pool is **resizable** ([`LanePool::resize`]) for the adaptive
/// space-time controller: growing spawns fresh workers onto the same
/// completion channel; shrinking drops the retired lanes' senders, so each
/// retired worker finishes every item already queued on its lane (their
/// completions still flow through the shared channel — a resize can never
/// lose an in-flight round-tagged completion) and then exits on its own.
/// Retired handles are joined lazily at shutdown/drop.
pub struct LanePool {
    proto: LaneProtocol<StdEnv, WorkItem, Completion>,
}

impl LanePool {
    pub fn new(lanes: usize, exec: Arc<dyn LaunchExecutor>) -> Self {
        Self { proto: LaneProtocol::new(lanes, Arc::new(ExecRunner { exec })) }
    }

    /// Change the resident lane count (clamped to >= 1) without losing any
    /// in-flight completion — the adaptive controller's reconfiguration
    /// primitive. Growing spawns fresh workers; shrinking retires the top
    /// lanes by dropping their senders: a retired worker drains everything
    /// already queued on its lane (completions arrive on the shared
    /// channel as usual, still carrying their original round tags) and
    /// exits. Returns immediately; retired workers are joined at
    /// shutdown/drop so a resize never blocks the round loop on a lane's
    /// backlog.
    pub fn resize(&mut self, lanes: usize) {
        self.proto.resize(lanes);
    }

    pub fn lanes(&self) -> usize {
        self.proto.lanes()
    }

    /// Queue one launch on its lane (clamped to the pool width — after a
    /// shrinking [`LanePool::resize`], plans targeting retired lanes fold
    /// onto the surviving ones, and the item's `lane` is rewritten so its
    /// completion reports the lane it actually executed on). Returns
    /// immediately; the item executes when the lane worker reaches it.
    // lint: hot-path
    pub fn dispatch(&mut self, item: WorkItem) {
        self.proto.dispatch(item);
    }

    /// Block for the next completion (any lane, any in-flight round).
    // lint: hot-path
    pub fn collect(&mut self) -> Result<Completion> {
        // lint: allow(hot-path-alloc) — `LaneProtocol::collect` is a
        // channel receive; a name collision with `Iterator::collect`,
        // not an allocation.
        self.proto
            .collect()
            .ok_or_else(|| anyhow!("lane workers terminated unexpectedly"))
    }

    /// Items dispatched but not yet collected.
    pub fn in_flight(&self) -> u64 {
        self.proto.in_flight()
    }

    /// Enable or disable cross-lane work stealing (off by default — with
    /// it off the pool behaves exactly like the pre-steal SPSC pool). The
    /// driver flips this around solo-calibration probe rounds.
    pub fn set_steal(&mut self, on: bool) {
        self.proto.set_steal(on);
    }

    /// Whether stealing is currently enabled.
    pub fn stealing(&self) -> bool {
        self.proto.stealing()
    }

    /// Minimum victim queue length before a thief may steal (>= 1).
    pub fn set_steal_min(&mut self, min: usize) {
        self.proto.set_steal_min(min);
    }

    /// Lifetime items stolen BY each lane slot (thief-side attribution).
    pub fn lane_steals(&self) -> Vec<u64> {
        self.proto.lane_steals()
    }

    /// Lifetime steals across all lanes.
    pub fn steals_total(&self) -> u64 {
        self.proto.steals_total()
    }

    /// Work-queue capacity growths (flat post-warmup == the dispatch and
    /// steal paths recycle their buffers without heap growth).
    pub fn queue_grows(&self) -> u64 {
        self.proto.queue_grows()
    }

    /// Close the queues, join every worker, and return the completions
    /// that finished but were never collected — the zero-lost-completions
    /// drain contract: `collected + shutdown().len() == dispatched` as
    /// long as every dispatched item executed.
    pub fn shutdown(mut self) -> Vec<Completion> {
        self.proto.shutdown_drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{InferenceRequest, Priority, ShapeClass};
    use std::collections::HashMap;
    use std::time::Duration;

    const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 8, n: 8, k: 8 };

    fn item(round: u64, index: usize, lane: usize, lanes_resident: usize) -> WorkItem {
        let now = Instant::now();
        WorkItem {
            round,
            index,
            lane,
            lanes_resident,
            launch: Launch {
                class: CLASS,
                entries: vec![InferenceRequest {
                    id: round * 1000 + index as u64,
                    tenant: 0,
                    class: CLASS,
                    payload: vec![],
                    arrived: now,
                    deadline: now,
                    priority: Priority::Normal,
                    trace_id: 0,
                }],
                r_bucket: 1,
            },
            spec: ModelSpec::Sgemm { m: 8, n: 8, k: 8 },
            weights: None,
            weights_marshal_s: 0.0,
            cost_hint: 0.0,
            executed_lane: lane,
            stolen: false,
            attempt: 0,
        }
    }

    /// Instant synthetic executor: echoes the item's bucket.
    struct EchoExec;
    impl LaunchExecutor for EchoExec {
        fn execute(&self, item: &WorkItem) -> Result<LaunchResult> {
            Ok(LaunchResult {
                outputs: Vec::new(),
                service_s: 1e-6,
                marshal_s: 0.0,
                r_bucket: item.launch.r_bucket,
            })
        }
    }

    /// Slow executor: forces items to still be queued at shutdown time.
    struct SlowExec(Duration);
    impl LaunchExecutor for SlowExec {
        fn execute(&self, item: &WorkItem) -> Result<LaunchResult> {
            std::thread::sleep(self.0);
            EchoExec.execute(item)
        }
    }

    struct FailExec;
    impl LaunchExecutor for FailExec {
        fn execute(&self, item: &WorkItem) -> Result<LaunchResult> {
            if item.index == 1 {
                Err(anyhow!("injected"))
            } else {
                EchoExec.execute(item)
            }
        }
    }

    #[test]
    fn per_lane_fifo_and_round_tags_echoed() {
        let mut pool = LanePool::new(2, Arc::new(EchoExec));
        for round in 0..4u64 {
            for lane in 0..2usize {
                pool.dispatch(item(round, lane, lane, 2));
            }
        }
        let mut per_lane: HashMap<usize, Vec<u64>> = HashMap::new();
        for _ in 0..8 {
            let c = pool.collect().unwrap();
            assert_eq!(c.lanes_resident, 2, "tag must ride the completion");
            assert_eq!(c.launch.entries[0].id, c.round * 1000 + c.index as u64);
            per_lane.entry(c.lane).or_default().push(c.round);
        }
        assert_eq!(pool.in_flight(), 0);
        for (lane, rounds) in per_lane {
            assert!(
                rounds.windows(2).all(|w| w[0] <= w[1]),
                "lane {lane} executed out of dispatch order: {rounds:?}"
            );
        }
    }

    #[test]
    fn shutdown_joins_with_zero_lost_completions() {
        let mut pool = LanePool::new(2, Arc::new(SlowExec(Duration::from_millis(1))));
        for i in 0..20usize {
            pool.dispatch(item(1, i, i % 2, 2));
        }
        // Collect a few live, then shut down with work still in flight.
        let mut collected = 0u64;
        for _ in 0..5 {
            pool.collect().unwrap();
            collected += 1;
        }
        let leftover = pool.shutdown();
        assert_eq!(
            collected + leftover.len() as u64,
            20,
            "every dispatched item must surface exactly once"
        );
    }

    #[test]
    fn prop_shutdown_under_load_never_loses_completions() {
        // Satellite of the model-check work: the real-time randomized
        // companion to the checker's exhaustive shutdown-drain proof.
        // Random lane widths, item counts, per-item delays, live-collect
        // counts, and mid-stream resizes; at a random depth the pool is
        // shut down with work still queued/in flight. Every dispatched
        // item must surface exactly once (live or in the drain), with its
        // round tag intact. Failures reproduce via the printed seed.
        use crate::util::prop::run_prop;
        run_prop("shutdown under load", 0x51D0, 24, |rng| {
            let lanes = 1 + rng.gen_range(4) as usize;
            let delay = Duration::from_micros(rng.gen_range(300));
            let mut pool = LanePool::new(lanes, Arc::new(SlowExec(delay)));
            let n_items = 1 + rng.gen_range(24) as usize;
            for i in 0..n_items {
                pool.dispatch(item(1 + (i / 7) as u64, i, i % lanes, lanes));
            }
            if rng.gen_bool(0.3) {
                pool.resize(1 + rng.gen_range(4) as usize);
            }
            let live = rng.gen_range(n_items as u64 + 1) as usize;
            let mut seen: Vec<bool> = vec![false; n_items];
            for _ in 0..live {
                let c = pool.collect().unwrap();
                assert!(!seen[c.index], "duplicated completion {}", c.index);
                seen[c.index] = true;
                assert_eq!(c.round, 1 + (c.index / 7) as u64, "round tag lost");
            }
            for c in pool.shutdown() {
                assert!(!seen[c.index], "duplicated completion {}", c.index);
                seen[c.index] = true;
                assert_eq!(c.round, 1 + (c.index / 7) as u64, "round tag lost");
            }
            let missing = seen.iter().filter(|&&s| !s).count();
            assert_eq!(missing, 0, "{missing} of {n_items} completions lost");
        });
    }

    struct PanicExec;
    impl LaunchExecutor for PanicExec {
        fn execute(&self, item: &WorkItem) -> Result<LaunchResult> {
            if item.index == 1 {
                panic!("boom");
            }
            EchoExec.execute(item)
        }
    }

    #[test]
    fn executor_panic_becomes_an_err_completion_and_worker_survives() {
        // Regression: a panicking executor used to kill the lane worker;
        // with sibling lanes alive the completion channel stayed open and
        // the driver hung forever on the wedged round. Now the panic is
        // caught per item and the SAME worker keeps serving later items.
        let mut pool = LanePool::new(1, Arc::new(PanicExec));
        for i in 0..4usize {
            pool.dispatch(item(1, i, 0, 1));
        }
        let mut errs = 0;
        let mut oks = 0;
        for _ in 0..4 {
            let c = pool.collect().unwrap();
            match c.result {
                Ok(_) => oks += 1,
                Err(e) => {
                    errs += 1;
                    assert!(format!("{e}").contains("panicked"), "got: {e}");
                }
            }
        }
        assert_eq!((oks, errs), (3, 1));
        assert_eq!(pool.in_flight(), 0, "nothing lost to the panic");
    }

    #[test]
    fn executor_errors_surface_per_item_and_pool_survives() {
        let mut pool = LanePool::new(1, Arc::new(FailExec));
        pool.dispatch(item(1, 0, 0, 1));
        pool.dispatch(item(1, 1, 0, 1));
        pool.dispatch(item(1, 2, 0, 1));
        let mut errs = 0;
        let mut oks = 0;
        for _ in 0..3 {
            let c = pool.collect().unwrap();
            match c.result {
                Ok(_) => oks += 1,
                Err(_) => errs += 1,
            }
        }
        assert_eq!((oks, errs), (2, 1), "one injected failure, pool stays up");
    }

    #[test]
    fn resize_grows_and_shrinks_without_losing_completions() {
        // The adaptive controller's reconfiguration primitive: dispatch a
        // burst, shrink mid-stream (retired lanes still owe completions),
        // grow again, keep dispatching — every item must surface exactly
        // once with its original round tag.
        let mut pool = LanePool::new(4, Arc::new(SlowExec(Duration::from_millis(1))));
        assert_eq!(pool.lanes(), 4);
        for i in 0..16usize {
            pool.dispatch(item(1, i, i % 4, 4));
        }
        pool.resize(2);
        assert_eq!(pool.lanes(), 2);
        // Items queued on retired lanes 2/3 still complete; new dispatches
        // clamp onto the surviving lanes.
        for i in 0..8usize {
            pool.dispatch(item(2, i, i % 4, 2));
        }
        pool.resize(3);
        assert_eq!(pool.lanes(), 3);
        for i in 0..6usize {
            pool.dispatch(item(3, i, i % 3, 3));
        }
        let mut per_round: HashMap<u64, usize> = HashMap::new();
        for _ in 0..30 {
            let c = pool.collect().unwrap();
            let expect_resident = c.round as usize + (c.round == 1) as usize * 3;
            assert_eq!(
                c.lanes_resident, expect_resident,
                "round {} must keep the tag it was dispatched with",
                c.round
            );
            *per_round.entry(c.round).or_default() += 1;
        }
        assert_eq!(pool.in_flight(), 0);
        assert_eq!(per_round[&1], 16);
        assert_eq!(per_round[&2], 8);
        assert_eq!(per_round[&3], 6);
        let leftover = pool.shutdown();
        assert!(leftover.is_empty());
    }

    /// Blocks on items with `round == 0` until the test opens the gate;
    /// signals entry so tests can wait until a worker is provably inside.
    struct BlockRound0 {
        gate: Arc<(std::sync::Mutex<(bool, u32)>, std::sync::Condvar)>,
    }
    impl BlockRound0 {
        #[allow(clippy::type_complexity)]
        fn new() -> (Arc<(std::sync::Mutex<(bool, u32)>, std::sync::Condvar)>, Self) {
            let gate = Arc::new((std::sync::Mutex::new((false, 0)), std::sync::Condvar::new()));
            (gate.clone(), BlockRound0 { gate })
        }
    }
    impl LaunchExecutor for BlockRound0 {
        fn execute(&self, item: &WorkItem) -> Result<LaunchResult> {
            if item.round == 0 {
                let (m, cv) = &*self.gate;
                let mut st = m.lock().unwrap();
                st.1 += 1;
                cv.notify_all();
                while !st.0 {
                    st = cv.wait(st).unwrap();
                }
            }
            EchoExec.execute(item)
        }
    }

    #[test]
    fn steal_rebalances_a_blocked_lane_and_tags_executed_lane() {
        let (gate, exec) = BlockRound0::new();
        let mut pool = LanePool::new(2, Arc::new(exec));
        pool.set_steal(true);
        assert!(pool.stealing());
        // Blocker onto lane 0; wait until a worker is stuck inside it.
        pool.dispatch(item(0, 99, 0, 2));
        {
            let (m, cv) = &*gate;
            let mut st = m.lock().unwrap();
            while st.1 < 1 {
                st = cv.wait(st).unwrap();
            }
        }
        // Backlog behind the blocker: the free worker must execute all of
        // it while the gate is closed — work conservation under imbalance.
        for i in 0..4usize {
            pool.dispatch(item(1, i, 0, 2));
        }
        for _ in 0..4 {
            let c = pool.collect().unwrap();
            assert_eq!(c.round, 1, "gate item cannot finish while closed");
            assert_eq!(c.lane, 0, "planned lane tag survives stealing");
            assert!(c.executed_lane < 2);
            assert_eq!(c.lanes_resident, 2, "round tag intact on stolen work");
            if c.stolen {
                assert_ne!(c.executed_lane, c.lane, "stolen implies a thief lane");
            }
        }
        assert!(pool.steals_total() >= 1, "at least one item crossed lanes");
        {
            let (m, cv) = &*gate;
            m.lock().unwrap().0 = true;
            cv.notify_all();
        }
        let c = pool.collect().unwrap();
        assert_eq!(c.round, 0);
        assert_eq!(pool.in_flight(), 0);
        assert!(pool.shutdown().is_empty());
    }

    #[test]
    fn steal_off_by_default_keeps_lanes_private() {
        let (gate, exec) = BlockRound0::new();
        let mut pool = LanePool::new(2, Arc::new(exec));
        assert!(!pool.stealing(), "stealing must be opt-in");
        pool.dispatch(item(0, 99, 0, 2));
        {
            let (m, cv) = &*gate;
            let mut st = m.lock().unwrap();
            while st.1 < 1 {
                st = cv.wait(st).unwrap();
            }
        }
        for i in 0..3usize {
            pool.dispatch(item(1, i, 0, 2));
        }
        {
            let (m, cv) = &*gate;
            m.lock().unwrap().0 = true;
            cv.notify_all();
        }
        for _ in 0..4 {
            let c = pool.collect().unwrap();
            assert_eq!(c.executed_lane, 0, "steal off: only the owner executes");
            assert!(!c.stolen);
        }
        assert_eq!(pool.steals_total(), 0);
        assert!(pool.shutdown().is_empty());
    }

    #[test]
    fn resize_clamps_to_one_lane() {
        let mut pool = LanePool::new(2, Arc::new(EchoExec));
        pool.resize(0);
        assert_eq!(pool.lanes(), 1, "a pool never goes below one lane");
        pool.dispatch(item(1, 0, 5, 1)); // lane id beyond width clamps
        let c = pool.collect().unwrap();
        assert_eq!(c.lane, 0);
    }

    #[test]
    fn prop_pipelined_rounds_keep_their_own_lane_tags() {
        // The cost-model-attribution property: run a depth-2 pipeline over
        // random rounds with random lane counts; while two rounds are in
        // flight, every completion must still carry the lane count ITS
        // round was planned with, and each round must complete exactly its
        // dispatched launch count.
        use crate::util::prop::run_prop;
        run_prop("pipelined round tags", 0xF16, 24, |rng| {
            let lanes = 1 + rng.gen_range(4) as usize;
            let mut pool = LanePool::new(lanes, Arc::new(EchoExec));
            let n_rounds = 3 + rng.gen_range(6) as u64;
            // round -> (lanes_resident, launches)
            let mut planned: HashMap<u64, (usize, usize)> = HashMap::new();
            let mut seen: HashMap<u64, usize> = HashMap::new();
            let mut in_flight: Vec<u64> = Vec::new();
            let mut outstanding: HashMap<u64, usize> = HashMap::new();
            let depth = 2usize;
            for round in 1..=n_rounds {
                let resident = 1 + rng.gen_range(lanes as u64) as usize;
                let launches = 1 + rng.gen_range(5) as usize;
                planned.insert(round, (resident, launches));
                for i in 0..launches {
                    pool.dispatch(item(round, i, i % lanes, resident));
                }
                in_flight.push(round);
                outstanding.insert(round, launches);
                while in_flight.len() > depth - 1 {
                    let c = pool.collect().unwrap();
                    let (resident, _) = planned[&c.round];
                    assert_eq!(
                        c.lanes_resident, resident,
                        "round {} completion mis-tagged while rounds {:?} in flight",
                        c.round, in_flight
                    );
                    *seen.entry(c.round).or_default() += 1;
                    let left = outstanding.get_mut(&c.round).unwrap();
                    *left -= 1;
                    if *left == 0 {
                        in_flight.retain(|&r| r != c.round);
                    }
                }
            }
            while pool.in_flight() > 0 {
                let c = pool.collect().unwrap();
                assert_eq!(c.lanes_resident, planned[&c.round].0);
                *seen.entry(c.round).or_default() += 1;
            }
            for (round, (_, launches)) in planned {
                assert_eq!(
                    seen.get(&round).copied().unwrap_or(0),
                    launches,
                    "round {round} lost or duplicated completions"
                );
            }
        });
    }
}
