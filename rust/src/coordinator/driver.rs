//! The coordinator driver: the serve loop gluing queues → scheduler →
//! super-kernel execution → SLO monitoring → metrics, across a pool of
//! one or more devices.
//!
//! This is the leader's request path. It is deliberately synchronous and
//! deterministic per round (the threaded frontend in `server/` pumps it);
//! every round, for **each device shard**:
//!   1. the shard's scheduler drains its queued problems into a launch plan
//!      (with `edf` on, planned against the shard's cost model: launches
//!      ordered by urgency and split to protect deadlines),
//!   2. each launch gathers operands, executes ONE PJRT executable, and
//!      scatters outputs,
//!   3. completions feed the SLO monitor (latency EWMA + deadline
//!      hit/miss), the metrics, and — with `edf` on — the shard's
//!      launch-latency predictor (measured marshal+execute duration),
//!   4. periodically the monitor evicts stragglers (relative to their
//!      device peers) and their queues drain.
//!
//! With `edf` on, admission additionally sheds requests whose minimal
//! immediate launch is already predicted past their deadline
//! ([`Reject::DeadlineInfeasible`], 504-style).
//!
//! With `lanes > 1` (space-time only), a round's launches are balanced
//! across **spatial execution lanes** by the scheduler and executed
//! *concurrently* here — one worker thread per lane over the shared PJRT
//! engine, all feeding one measurement channel. Every measured duration is
//! tagged with the round's resident lane count so the cost model's
//! co-location interference stretch calibrates from real overlapped
//! launches; per-lane launch counts and busy time ride the device
//! snapshot.
//!
//! Sharding (the multi-device generalization): tenants are assigned to
//! devices at registration time by the [`placement`] layer — least-loaded
//! with shape-class affinity, so fusion opportunities are never split
//! across shards. Each shard owns an independent scheduler instance and a
//! bounded [`QueueSet`]; admission additionally enforces a **global** cap
//! (`queue_cap`) across the whole pool, shedding with
//! [`Reject::Overloaded`] instead of growing without bound.
//!
//! [`placement`]: crate::coordinator::placement

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::ServerConfig;
use crate::coordinator::costmodel::{CostModel, SharedCostModel};
use crate::coordinator::fusion_cache::{FusionCache, FusionCacheStats};
use crate::coordinator::monitor::{Eviction, MonitorConfig, SloMonitor};
use crate::coordinator::placement::DevicePlacer;
use crate::coordinator::queue::QueueSet;
use crate::coordinator::request::{
    InferenceRequest, InferenceResponse, Reject, RequestId, ShapeClass,
};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::superkernel::{Flavor, LaunchResult, SuperKernelExec};
use crate::coordinator::tenant::TenantRegistry;
use crate::metrics::{DeviceSnapshot, MetricsRegistry};
use crate::runtime::{HostTensor, PjrtEngine};
use crate::util::prng::Rng;

/// Outcome of one scheduling round (all devices).
#[derive(Debug, Default)]
pub struct RoundOutcome {
    pub responses: Vec<InferenceResponse>,
    pub rejections: Vec<(RequestId, Reject)>,
    pub evictions: Vec<Eviction>,
    /// Total launches across the pool this round.
    pub launches: usize,
    /// Launches per device this round (index == device id).
    pub launches_per_device: Vec<usize>,
}

/// One device shard: its own admission queues, scheduler instance, and
/// lifetime counters.
struct DeviceShard {
    queues: QueueSet,
    scheduler: Box<dyn Scheduler>,
    /// Launch-latency predictor for this device (Some iff EDF planning or
    /// multi-lane execution is on): shared with the shard's scheduler, fed
    /// by measured launch durations after every execution.
    cost_model: Option<SharedCostModel>,
    launches: u64,
    superkernel_launches: u64,
    drained: u64,
    /// Fused launches the EDF planner split to protect a deadline.
    deadline_splits: u64,
    /// Launches executed per spatial lane (index == lane id).
    lane_launches: Vec<u64>,
    /// Busy seconds (marshal + execute) accumulated per spatial lane.
    lane_busy_s: Vec<f64>,
    flops: f64,
}

/// The coordinator.
pub struct Coordinator {
    engine: Arc<PjrtEngine>,
    pub tenants: TenantRegistry,
    shards: Vec<DeviceShard>,
    placer: DevicePlacer<ShapeClass>,
    /// Global admission cap across all shards.
    queue_cap: usize,
    /// Deadline-aware (EDF) planning on (space-time only).
    edf: bool,
    /// Spatial execution lanes per device (space-time only; 1 == serial
    /// rounds, the pre-lane driver).
    lanes: usize,
    /// Safety margin (seconds) for deadline budgets and admission checks.
    deadline_slack: f64,
    /// Requests judged deadline-infeasible at admission. Every
    /// `PROBE_EVERY`-th one is admitted anyway as a *probe*: its launch
    /// feeds a fresh measurement back to the cost model, so a predictor
    /// inflated by one anomalously slow launch cannot lock a class out
    /// forever (no launches → no observations → no recovery).
    infeasible_seen: u64,
    flavor: Flavor,
    /// Behind a mutex because spatial lanes execute concurrently; the lock
    /// is held only for lookups/builds, never across a PJRT execution.
    fusion_cache: Mutex<FusionCache>,
    monitor: SloMonitor,
    pub metrics: Arc<MetricsRegistry>,
    next_id: RequestId,
    rounds_since_check: u32,
    /// Monitor window length, in scheduling rounds.
    check_every: u32,
    /// Lifetime round counter (drives the solo-calibration probe cadence).
    rounds_total: u64,
    started: Instant,
}

/// With `lanes > 1`, every `SOLO_PROBE_EVERY`-th round executes serially
/// even when the plan spans several lanes: overlapped measurements alone
/// cannot disentangle solo latency from the interference stretch (the
/// stretch EWMA would absorb any solo-track bias forever), so the solo
/// track needs periodic un-overlapped ground truth — the same recovery
/// valve pattern as the admission probe (`PROBE_EVERY`).
const SOLO_PROBE_EVERY: u64 = 32;

impl Coordinator {
    /// Build from config: loads the manifest, registers tenants, places
    /// them on the device pool, picks the scheduler, and pre-warms the
    /// executables the workload will need.
    pub fn new(cfg: &ServerConfig) -> Result<Self> {
        Self::with_flavor(cfg, Flavor::Xla)
    }

    pub fn with_flavor(cfg: &ServerConfig, flavor: Flavor) -> Result<Self> {
        let engine = Arc::new(PjrtEngine::new(&cfg.artifacts_dir)?);
        let tenants = TenantRegistry::from_configs(&cfg.tenants)
            .map_err(|e| anyhow::anyhow!(e))?;
        // R buckets from the manifest (all kinds share aot.py's bucket set).
        let mut buckets = engine.manifest().r_buckets("batched_gemm", flavor.as_str());
        if buckets.is_empty() {
            buckets = vec![1];
        }
        // Fail fast: every tenant's shape class must have lowered artifacts
        // (the catalog is fixed at `make artifacts` time).
        for t in tenants.iter() {
            let class = t.spec.shape_class();
            let servable = engine
                .manifest()
                .find(class.kind, flavor.as_str(), class.mnk(), buckets[0])
                .or_else(|| {
                    if class.kind == "batched_gemm" {
                        None
                    } else {
                        engine.manifest().find(class.kind, flavor.as_str(), (0, 0, 0), buckets[0])
                    }
                })
                .is_some();
            if !servable {
                return Err(anyhow::anyhow!(
                    "tenant {}: no AOT artifact for shape class {class} \
                     (lowered classes are fixed at `make artifacts` time)",
                    t.name
                ));
            }
        }
        let policy = if cfg.split_exact {
            crate::coordinator::batcher::PaddingPolicy::SplitExact
        } else {
            crate::coordinator::batcher::PaddingPolicy::PadToBucket
        };
        // Place tenants on the device pool: least-loaded, class-affine
        // (load weight = per-request FLOPs of the tenant's shape class).
        let devices = cfg.devices.max(1);
        let tenant_classes: Vec<_> = tenants
            .iter()
            .map(|t| {
                let class = t.spec.shape_class();
                (class, class.flops())
            })
            .collect();
        let placer = DevicePlacer::new(&tenant_classes, devices);
        // Per-shard queues enforce only the per-tenant depth; the pool-wide
        // `queue_cap` spans shards, so `submit` enforces it and records
        // sheds on the target shard's QueueSet counter.
        //
        // Each shard's QueueSet is indexed by GLOBAL tenant id (O(devices x
        // tenants) queue slots, most permanently empty). That keeps the
        // schedulers device-blind — no id remapping between shards and
        // launch entries — at the cost of per-round backlogged() scans over
        // empty queues; compact per-shard id maps are a follow-up if tenant
        // counts grow past the low hundreds.
        // Deadline-aware (EDF) planning and spatial lanes only apply to the
        // space-time scheduler; each shard gets its own cost model so
        // calibration follows the device the launches actually ran on. The
        // cost model exists whenever lanes > 1 too — multi-lane rounds need
        // it for makespan balancing and the co-location interference term
        // even without EDF.
        let spacetime = cfg.scheduler == crate::config::SchedulerKind::SpaceTime;
        let edf = cfg.edf && spacetime;
        let lanes = if spacetime { cfg.lanes.max(1) } else { 1 };
        let shards = (0..devices)
            .map(|_| {
                let cost_model: Option<SharedCostModel> = if edf || lanes > 1 {
                    Some(Arc::new(Mutex::new(CostModel::new())))
                } else {
                    None
                };
                let scheduler = crate::coordinator::scheduler::make_scheduler_spatial(
                    cfg.scheduler,
                    buckets.clone(),
                    cfg.max_batch as usize,
                    policy,
                    cfg.slo_aware,
                    lanes,
                    cost_model.clone(),
                    if edf { Some(cfg.deadline_slack) } else { None },
                );
                DeviceShard {
                    queues: QueueSet::new(tenants.len(), cfg.queue_depth),
                    scheduler,
                    cost_model,
                    launches: 0,
                    superkernel_launches: 0,
                    drained: 0,
                    deadline_splits: 0,
                    lane_launches: vec![0; lanes],
                    lane_busy_s: vec![0.0; lanes],
                    flops: 0.0,
                }
            })
            .collect();
        let device_map: Vec<usize> =
            (0..tenants.len()).map(|t| placer.device_of(t)).collect();
        let monitor = SloMonitor::new(
            MonitorConfig {
                enabled: cfg.eviction_enabled,
                threshold: cfg.eviction_threshold,
                strikes: cfg.eviction_strikes,
                ..Default::default()
            },
            &tenants,
        )
        .with_device_map(device_map);
        Ok(Self {
            engine,
            tenants,
            shards,
            placer,
            queue_cap: cfg.queue_cap,
            edf,
            lanes,
            deadline_slack: cfg.deadline_slack.max(0.0),
            infeasible_seen: 0,
            flavor,
            fusion_cache: Mutex::new(FusionCache::new(256)),
            monitor,
            metrics: Arc::new(MetricsRegistry::new()),
            next_id: 0,
            rounds_since_check: 0,
            check_every: 16,
            rounds_total: 0,
            started: Instant::now(),
        })
    }

    pub fn engine(&self) -> &Arc<PjrtEngine> {
        &self.engine
    }

    pub fn scheduler_label(&self) -> &'static str {
        self.shards[0].scheduler.label()
    }

    /// Devices in the pool.
    pub fn devices(&self) -> usize {
        self.shards.len()
    }

    /// Which device a tenant's requests execute on.
    pub fn device_of(&self, tenant: usize) -> usize {
        self.placer.device_of(tenant)
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Whether deadline-aware (EDF) planning is active.
    pub fn deadline_aware(&self) -> bool {
        self.edf
    }

    /// Spatial execution lanes per device (1 == serial rounds).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The launch-latency predictor of one device shard (None when EDF
    /// planning is off or the device is unknown).
    pub fn cost_model(&self, device: usize) -> Option<&SharedCostModel> {
        self.shards.get(device).and_then(|s| s.cost_model.as_ref())
    }

    /// Requests shed by the global admission cap over the lifetime.
    pub fn shed_total(&self) -> u64 {
        self.shards.iter().map(|s| s.queues.shed).sum()
    }

    /// Batcher statistics summed across the pool (None for non-batching
    /// schedulers).
    pub fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        let mut merged: Option<crate::coordinator::batcher::BatcherStats> = None;
        for shard in &self.shards {
            if let Some(bs) = shard.scheduler.batcher_stats() {
                let m = merged.get_or_insert_with(Default::default);
                m.launches += bs.launches;
                m.problems += bs.problems;
                m.padded_lanes += bs.padded_lanes;
            }
        }
        merged
    }

    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.queues.total_pending()).sum()
    }

    /// Per-device counters (index == device id).
    pub fn device_snapshots(&self) -> Vec<DeviceSnapshot> {
        self.shards
            .iter()
            .enumerate()
            .map(|(d, s)| DeviceSnapshot {
                device: d,
                tenants: self.placer.members(d).len() as u64,
                pending: s.queues.total_pending() as u64,
                launches: s.launches,
                superkernel_launches: s.superkernel_launches,
                drained: s.drained,
                shed: s.queues.shed,
                deadline_splits: s.deadline_splits,
                cost_calibration_error: s
                    .cost_model
                    .as_ref()
                    .map_or(0.0, |cm| cm.lock().unwrap().calibration_error()),
                lane_launches: s.lane_launches.clone(),
                lane_busy_s: s.lane_busy_s.clone(),
                lane_calibration: s
                    .cost_model
                    .as_ref()
                    .map_or_else(Vec::new, |cm| cm.lock().unwrap().lane_calibration()),
                flops: s.flops,
            })
            .collect()
    }

    /// Pre-compile every executable this coordinator's tenants can hit, so
    /// the serving path never compiles.
    pub fn warmup(&self) -> Result<usize> {
        let kinds: std::collections::BTreeSet<&'static str> = self
            .tenants
            .iter()
            .map(|t| t.spec.shape_class().kind)
            .collect();
        let flavor = self.flavor.as_str();
        Ok(self.engine.warmup(|a| {
            a.impl_ == flavor && kinds.contains(a.kind.as_str())
        })?)
    }

    /// Submit a request for `tenant` with the given payload tensors.
    ///
    /// Admission is bounded twice: a global cap across the pool
    /// ([`Reject::Overloaded`], 429-style shed) and the per-tenant queue
    /// depth ([`Reject::QueueFull`]).
    pub fn submit(
        &mut self,
        tenant: usize,
        payload: Vec<HostTensor>,
    ) -> Result<RequestId, Reject> {
        let t = self
            .tenants
            .get(tenant)
            .ok_or_else(|| Reject::BadRequest(format!("unknown tenant {tenant}")))?;
        if !t.is_servable() {
            self.metrics.tenant(&t.name).record_rejection();
            return Err(Reject::TenantEvicted);
        }
        let shapes = t.spec.payload_shapes();
        if payload.len() != shapes.len() {
            return Err(Reject::BadRequest(format!(
                "expected {} payload tensors, got {}",
                shapes.len(),
                payload.len()
            )));
        }
        for (p, want) in payload.iter().zip(&shapes) {
            if &p.shape != want {
                return Err(Reject::BadRequest(format!(
                    "payload shape {:?} != expected {:?}",
                    p.shape, want
                )));
            }
        }
        let name = t.name.clone();
        let slo_ms = t.slo_ms;
        let class = t.spec.shape_class();
        let device = self.placer.device_of(tenant);
        // Deadline-aware admission: a request whose *minimal immediate*
        // launch is already predicted past its deadline is lost no matter
        // what the planner does — shed it now (504-style) instead of
        // queueing doomed work (DARIS, arXiv:2504.08795).
        if self.edf {
            if let Some(cm) = &self.shards[device].cost_model {
                let infeasible = cm
                    .lock()
                    .unwrap()
                    .deadline_infeasible(class, slo_ms / 1e3, self.deadline_slack);
                if infeasible {
                    self.infeasible_seen += 1;
                    // Recovery valve: admit every PROBE_EVERY-th infeasible
                    // request so its measured launch can deflate a predictor
                    // stuck high (see `infeasible_seen`). The probe at worst
                    // misses its deadline — which is counted, not hidden.
                    const PROBE_EVERY: u64 = 16;
                    if self.infeasible_seen % PROBE_EVERY != 0 {
                        self.metrics.tenant(&name).record_rejection();
                        return Err(Reject::DeadlineInfeasible);
                    }
                }
            }
        }
        // Global admission cap across every shard: shed, don't grow.
        if self.pending() >= self.queue_cap {
            self.shards[device].queues.record_shed();
            self.metrics.tenant(&name).record_rejection();
            return Err(Reject::Overloaded);
        }
        let id = self.next_id;
        self.next_id += 1;
        let arrived = Instant::now();
        let req = InferenceRequest {
            id,
            tenant,
            class,
            payload,
            arrived,
            deadline: arrived + std::time::Duration::from_secs_f64(slo_ms / 1e3),
        };
        match self.shards[device].queues.push(req) {
            Ok(()) => Ok(id),
            Err(rej) => {
                self.metrics.tenant(&name).record_rejection();
                Err(rej)
            }
        }
    }

    /// Synthesize a random request payload for a tenant (tests/benches).
    pub fn random_payload(&self, tenant: usize, rng: &mut Rng) -> Vec<HostTensor> {
        self.tenants
            .get(tenant)
            .map(|t| {
                t.spec
                    .payload_shapes()
                    .iter()
                    .map(|s| HostTensor::random(s, rng))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Run one scheduling round: one `RoundPlan` per device, executed
    /// shard by shard (the pool's devices are independent; on real
    /// multi-GPU hardware these launches run concurrently — the CPU-PJRT
    /// substrate executes them back-to-back, which preserves scheduling
    /// semantics and per-device accounting). Within a shard, a plan that
    /// spans several spatial lanes executes them **concurrently**: one
    /// worker thread per lane, all feeding one measurement channel whose
    /// results calibrate the shard's cost model (solo latency AND the
    /// co-location interference stretch at the observed lane count).
    pub fn run_round(&mut self) -> Result<RoundOutcome> {
        let mut outcome = RoundOutcome {
            launches_per_device: vec![0; self.shards.len()],
            ..Default::default()
        };
        let exec = SuperKernelExec::new(&self.engine, self.flavor);
        self.rounds_total += 1;
        let probe_solo = self.lanes > 1 && self.rounds_total % SOLO_PROBE_EVERY == 0;
        for (device, shard) in self.shards.iter_mut().enumerate() {
            let now = Instant::now();
            let plan = shard.scheduler.plan_round_at(&mut shard.queues, now);
            outcome.launches += plan.launches.len();
            outcome.launches_per_device[device] = plan.launches.len();
            shard.launches += plan.launches.len() as u64;
            shard.drained += plan.drained as u64;
            shard.deadline_splits += plan.deadline_splits as u64;
            if plan.launches.is_empty() {
                continue;
            }
            let (hits_before, misses_before) = {
                let c = self.fusion_cache.lock().unwrap();
                (c.stats.hits, c.stats.misses)
            };
            // Execute the plan: serial when everything shares one lane (or
            // on a solo-calibration probe round), overlapped lane workers
            // otherwise. Either way `results[i]` holds launch i's outcome
            // and completion instant.
            let lanes_used = if probe_solo { 1 } else { plan.lanes_used() };
            let mut results: Vec<Option<(LaunchResult, Instant)>> = Vec::new();
            results.resize_with(plan.launches.len(), || None);
            if lanes_used <= 1 {
                for (i, launch) in plan.launches.iter().enumerate() {
                    let res = exec.execute(launch, &self.tenants, &self.fusion_cache)?;
                    results[i] = Some((res, Instant::now()));
                }
            } else {
                let mut groups: Vec<Vec<usize>> = vec![Vec::new(); plan.n_lanes];
                for i in 0..plan.launches.len() {
                    groups[plan.lane(i).min(plan.n_lanes - 1)].push(i);
                }
                let (tx, rx) = std::sync::mpsc::channel();
                let launches = &plan.launches;
                let tenants = &self.tenants;
                let cache = &self.fusion_cache;
                let exec_ref = &exec;
                std::thread::scope(|scope| {
                    for group in groups.iter().filter(|g| !g.is_empty()) {
                        let tx = tx.clone();
                        scope.spawn(move || {
                            for &i in group {
                                let res = exec_ref.execute(&launches[i], tenants, cache);
                                let done = Instant::now();
                                if tx.send((i, res, done)).is_err() {
                                    return;
                                }
                            }
                        });
                    }
                });
                drop(tx);
                // The scope joined every worker: the channel holds one
                // message per launch. The first execution error aborts the
                // round, mirroring the serial path.
                for (i, res, done) in rx {
                    results[i] = Some((res?, done));
                }
            }
            // Aggregate cache accounting (per-launch attribution is
            // meaningless once launches overlap).
            {
                let c = self.fusion_cache.lock().unwrap();
                for _ in hits_before..c.stats.hits {
                    self.metrics.record_cache(true);
                }
                for _ in misses_before..c.stats.misses {
                    self.metrics.record_cache(false);
                }
            }
            for (i, launch) in plan.launches.iter().enumerate() {
                let (res, done) = results[i].take().expect("every launch executed");
                let fused = launch.entries.len();
                if fused > 1 {
                    self.metrics.record_superkernel_launch();
                    shard.superkernel_launches += 1;
                } else {
                    self.metrics.record_kernel_launch();
                }
                // Calibrate this shard's launch-latency predictor with the
                // measured end-to-end launch duration (marshal + execute —
                // what a deadline actually waits on), tagged with how many
                // lanes were concurrently resident so the interference
                // stretch learns from overlapped rounds.
                if let Some(cm) = &shard.cost_model {
                    cm.lock().unwrap().observe_concurrent(
                        launch.class,
                        launch.r_bucket,
                        lanes_used,
                        res.service_s + res.marshal_s,
                    );
                }
                let lane = plan.lane(i).min(shard.lane_launches.len().saturating_sub(1));
                shard.lane_launches[lane] += 1;
                shard.lane_busy_s[lane] += res.service_s + res.marshal_s;
                for (entry, output) in launch.entries.iter().zip(res.outputs) {
                    let latency_s = done.duration_since(entry.arrived).as_secs_f64();
                    // One deadline verdict per response, fed to BOTH the
                    // metrics registry (status JSON / serve table) and the
                    // SLO monitor (eviction-adjacent reporting) from this
                    // single point so the two attainment views can't
                    // diverge.
                    let met = done <= entry.deadline;
                    let tenant = self.tenants.get(entry.tenant).expect("tenant");
                    let handle = self.metrics.tenant(&tenant.name);
                    handle.record_completion(
                        (latency_s * 1e9) as u64,
                        (res.service_s * 1e9) as u64,
                        entry.class.flops(),
                    );
                    handle.record_deadline(met);
                    shard.flops += entry.class.flops();
                    self.monitor.observe(entry.tenant, res.service_s);
                    self.monitor.observe_deadline(entry.tenant, met);
                    outcome.responses.push(InferenceResponse {
                        id: entry.id,
                        tenant: entry.tenant,
                        output,
                        latency_s,
                        service_s: res.service_s,
                        fused_r: fused,
                    });
                }
            }
        }
        // Periodic straggler check (stragglers judged against same-device
        // peers — see SloMonitor::with_device_map).
        self.rounds_since_check += 1;
        if self.rounds_since_check >= self.check_every {
            self.rounds_since_check = 0;
            let evictions = self.monitor.check(&mut self.tenants);
            for ev in &evictions {
                let name = self.tenants.get(ev.tenant).expect("tenant").name.clone();
                self.metrics.tenant(&name).record_eviction();
                // Drop the evicted tenant's device-resident weights, fail
                // everything it still has queued, and release its load
                // from the placement accounting (a later re-registration
                // re-joins its class via `DevicePlacer::readmit`).
                self.fusion_cache.lock().unwrap().invalidate_tenant(ev.tenant);
                let device = self.placer.device_of(ev.tenant);
                for req in self.shards[device].queues.drain_tenant(ev.tenant) {
                    outcome.rejections.push((req.id, Reject::TenantEvicted));
                }
                self.placer.release(ev.tenant);
            }
            outcome.evictions = evictions;
        }
        Ok(outcome)
    }

    /// Run rounds until all queues drain; returns every response.
    pub fn run_until_drained(&mut self) -> Result<Vec<InferenceResponse>> {
        let mut all = Vec::new();
        while self.pending() > 0 {
            let out = self.run_round()?;
            all.extend(out.responses);
        }
        Ok(all)
    }

    /// Force an immediate monitor window check (tests/benches).
    pub fn force_check(&mut self) -> Vec<Eviction> {
        let evictions = self.monitor.check(&mut self.tenants);
        for ev in &evictions {
            self.fusion_cache.lock().unwrap().invalidate_tenant(ev.tenant);
            self.placer.release(ev.tenant);
        }
        evictions
    }

    /// Re-admit a previously evicted tenant: health returns to `Healthy`,
    /// the monitor's straggler state resets (a fresh EWMA — not the
    /// history that got it evicted), and the placement layer re-joins the
    /// tenant to its shape class's device (least-loaded fallback when the
    /// whole class left). Returns the device it landed on. A tenant that
    /// was never evicted keeps its current placement.
    pub fn readmit_tenant(&mut self, tenant: usize) -> Result<usize, Reject> {
        let t = self
            .tenants
            .get_mut(tenant)
            .ok_or_else(|| Reject::BadRequest(format!("unknown tenant {tenant}")))?;
        if t.health != crate::coordinator::tenant::Health::Evicted {
            return Ok(self.placer.device_of(tenant));
        }
        t.health = crate::coordinator::tenant::Health::Healthy;
        self.monitor.reset(tenant);
        let device = self.placer.readmit(tenant);
        self.monitor.set_device(tenant, device);
        Ok(device)
    }

    /// Feed an out-of-band latency observation to the SLO monitor —
    /// the anomaly-injection hook used by failure tests and the
    /// straggler_eviction example (the serve path observes automatically).
    pub fn monitor_observe(&mut self, tenant: usize, service_s: f64) {
        self.monitor.observe(tenant, service_s);
    }

    pub fn monitor(&self) -> &SloMonitor {
        &self.monitor
    }

    /// Fusion-cache accounting (weight-operand reuse across launches).
    pub fn fusion_cache_stats(&self) -> FusionCacheStats {
        self.fusion_cache.lock().unwrap().stats
    }

    /// Replace the fusion cache (benches/ablations: e.g. capacity 1 to
    /// force the cold path). Serving uses the default capacity-256 cache.
    pub fn set_fusion_cache_capacity(&mut self, capacity: usize) {
        *self.fusion_cache.lock().unwrap() = FusionCache::new(capacity);
    }

    /// Metrics snapshot over the coordinator's lifetime, including the
    /// per-device section.
    pub fn snapshot(&self) -> crate::metrics::Snapshot {
        let mut snap = self.metrics.snapshot(self.started.elapsed().as_secs_f64());
        snap.devices = self.device_snapshots();
        snap
    }
}

#[cfg(test)]
mod tests {
    // Coordinator tests require artifacts; see
    // rust/tests/integration_coordinator.rs. Pure plumbing tests here.
    use super::*;
    use crate::config::ServerConfig;

    #[test]
    fn bad_artifact_dir_fails_fast() {
        let cfg = ServerConfig {
            artifacts_dir: "/nonexistent".into(),
            ..Default::default()
        };
        assert!(Coordinator::new(&cfg).is_err());
    }
}
