//! The coordinator driver: the serve loop gluing queues → scheduler →
//! super-kernel execution → SLO monitoring → metrics, across a pool of
//! one or more devices.
//!
//! ## Pipelined persistent-lane execution
//!
//! Execution runs on a **persistent per-lane worker pool** per device
//! shard ([`LanePool`]): one worker thread per spatial lane, spawned once
//! at construction, fed through per-lane FIFO work queues, joined on
//! shutdown. The old driver re-spawned a `thread::scope` per round and
//! ran plan → execute strictly serially; now the round loop is a
//! **software pipeline** of depth `pipeline_depth`:
//!
//! * each [`Coordinator::run_round`] call plans round N+1 (drains
//!   admission, runs the EDF/spatial-lane planner, **marshals weights**
//!   through the fusion cache) and dispatches it to the lane workers,
//! * then collects completed launches until at most `pipeline_depth - 1`
//!   rounds remain in flight — so while round N executes on the workers,
//!   the driver is already planning and marshaling round N+1.
//!
//! Every dispatched launch is **round-tagged** (round id + the lane count
//! its round planned to keep resident); the tag rides the completion
//! back, so measurements, deadline accounting, and cost-model feedback
//! are attributed to the correct round even while rounds overlap. The
//! tag is the round's *planned intra-round* concurrency: at depth > 1 a
//! launch may additionally overlap the tail of the previous round on
//! other lanes — that residue is part of the pipelined substrate the
//! model calibrates against. The exception is the periodic
//! solo-calibration probe ([`SOLO_PROBE_EVERY`]), whose measurements
//! exist precisely to keep the solo track clean: probe rounds drain the
//! shard first and are collected before the next plan, so they execute
//! genuinely un-overlapped (a deliberate 1-in-32 pipeline bubble).
//! `pipeline_depth = 1` collects each round before the next plan — the
//! old serial driver's behavior (same launch plans, same completion
//! processing order on a single lane).
//!
//! The round hot path is **allocation-free after warmup**: each shard's
//! [`RoundArena`] recycles the plan's launch and lane vectors across
//! rounds (the scheduler fills them in place via
//! [`Scheduler::plan_round_into`]; dispatching drains them, keeping
//! capacity), the scheduler and batcher keep their own drain/bucketing
//! scratch, tenant metric handles are interned by id (no per-event name
//! lookup or `String` clone), and completions stream straight into
//! responses — no per-round result buffers or lane-group vectors. The
//! documented exception is per-launch *owned* data: each launch's entry
//! vector (launches carry their requests away with them) and, for
//! weighted kinds, the fusion-cache lookup key. The arena counts buffer
//! growths; after warmup that counter stays flat (asserted in tests).
//!
//! Snapshots read **atomic mirrors** (per-lane launch/busy counters and
//! cost-model calibration, updated at completion processing) instead of
//! locking each shard's cost model — `snapshot()`/status JSON never
//! contends with planning or execution.
//!
//! ## Adaptive space-time control
//!
//! With `[controller] adaptive = true`, each shard carries an
//! [`AdaptiveController`] that every `dwell_rounds` rounds re-decides the
//! resident lane count and effective pipeline depth from observed
//! signals: backlog and offered-load EWMA from the shard's `QueueSet`,
//! launches/requests-per-round and mean launch duration from its
//! [`SignalTracker`], the calibrated per-lane-count interference stretch
//! from its cost model, and windowed deadline attainment. A lane change
//! resizes the persistent pool in place ([`LanePool::resize`] — retiring
//! workers drain their queues, so no round-tagged completion is ever
//! lost) and re-targets the scheduler (`Scheduler::set_lanes`); the
//! recycled arena and scheduler scratch survive, keeping the hot path
//! allocation-free across reconfigurations. `adaptive = false` (default)
//! constructs no controller and runs the static paths bit-for-bit.
//!
//! ## Work-conserving execution (`[server] steal = true`)
//!
//! With stealing enabled, the per-lane queues become stealable deques: a
//! lane that drains early takes the back of the predicted-longest
//! remaining lane instead of idling until the round barrier
//! ([`LanePool::set_steal`]; victim selection is cost-guided via each
//! item's `cost_hint`, filled from the shard cost model's concurrent
//! prediction). Completions keep their *planned* round/lane tags — cost
//! attribution and round accounting are unchanged — and additionally
//! report `executed_lane`/`stolen`, which feed the per-lane steal
//! counters exported through [`DeviceSnapshot`] (status JSON and the
//! serve table). The scheduler overpacks the predicted-longest lane
//! slightly when stealing is on (steal-aware overpacking), the adaptive
//! controller tracks a steal-rate EWMA as a rebalance signal, and the
//! driver force-disables stealing around solo-calibration probe rounds so
//! probe measurements stay un-overlapped. Stealing also backstops
//! launch-level faults: a failed launch is retried exactly once on
//! another lane through the same re-dispatch path (counted in
//! `launch_retries`); a second failure drops the launch's entries and
//! serving continues. `steal = false` (default) runs the private SPSC
//! queues bit-for-bit.
//!
//! ## Scheduling semantics (unchanged)
//!
//! Every round, for each device shard: the shard's scheduler drains its
//! queued problems into a launch plan (with `edf` on, planned against the
//! shard's cost model: launches ordered by urgency and split to protect
//! deadlines); each launch gathers operands, executes ONE PJRT
//! executable, and scatters outputs; completions feed the SLO monitor,
//! the metrics, and — with `edf` on — the shard's launch-latency
//! predictor; periodically the monitor evicts stragglers. With `edf` on,
//! admission sheds requests whose minimal immediate launch is already
//! predicted past their deadline ([`Reject::DeadlineInfeasible`]). With
//! `lanes > 1` (space-time only), a round's launches are balanced across
//! spatial lanes and executed concurrently, each measurement tagged with
//! the round's resident lane count so the cost model's interference
//! stretch calibrates from real overlapped launches.
//!
//! Sharding: tenants are assigned to devices at registration time by the
//! [`placement`] layer — least-loaded with shape-class affinity. Each
//! shard owns an independent scheduler instance, a bounded [`QueueSet`],
//! and its own fusion cache (placement keeps tenants device-disjoint, so
//! weight-cache keys never span shards). Admission additionally enforces
//! a **global** cap (`queue_cap`), shedding with [`Reject::Overloaded`].
//!
//! [`placement`]: crate::coordinator::placement

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::ServerConfig;
use crate::coordinator::controller::{
    AdaptiveController, ControlSignals, ControllerParams, Decision, SignalTracker,
};
use crate::coordinator::costmodel::{CostModel, SharedCostModel};
use crate::coordinator::fusion_cache::{FusionCache, FusionCacheStats};
use crate::coordinator::lanepool::{Completion, LanePool, LaunchExecutor, PjrtExecutor, WorkItem};
use crate::coordinator::monitor::{Eviction, MonitorConfig, SloMonitor};
use crate::coordinator::placement::DevicePlacer;
use crate::coordinator::queue::QueueSet;
use crate::coordinator::request::{
    InferenceRequest, InferenceResponse, Reject, RequestContext, RequestId, ShapeClass,
};
use crate::coordinator::scheduler::{RoundPlan, Scheduler};
use crate::coordinator::superkernel::{Flavor, SuperKernelExec};
use crate::coordinator::tenant::TenantRegistry;
use crate::metrics::{DeviceSnapshot, MetricsRegistry, TenantMetrics};
use crate::util::sync::lock_recover;
use crate::runtime::{HostTensor, PjrtEngine};
use crate::util::prng::Rng;

/// Outcome of one scheduling round (all devices).
///
/// With `pipeline_depth > 1`, `responses` belong to the round(s) whose
/// completions were collected this call — typically the round *dispatched
/// by the previous call* — while `launches` counts the round planned and
/// dispatched now. Callers that need every response drained use
/// [`Coordinator::run_until_drained`] (or loop while
/// [`Coordinator::in_flight_rounds`] is non-zero).
#[derive(Debug, Default)]
pub struct RoundOutcome {
    pub responses: Vec<InferenceResponse>,
    pub rejections: Vec<(RequestId, Reject)>,
    pub evictions: Vec<Eviction>,
    /// Launches planned and dispatched across the pool this round.
    pub launches: usize,
    /// Launches per device this round (index == device id).
    pub launches_per_device: Vec<usize>,
}

/// A controller decision planned for one shard but not yet applied — the
/// worker-side/committer-side seam the cluster tier journals a
/// reconfiguration through before it takes effect.
#[derive(Debug, Clone, Copy)]
pub struct ControlPlan {
    pub device: usize,
    pub decision: Decision,
}

/// Reusable per-shard round-plan storage: the scheduler fills the plan in
/// place, dispatch drains the launch vector (keeping its capacity), and
/// the next round reuses both vectors. `grows` counts capacity growths
/// *after warmup* — the allocation counter the hot-path tests pin to
/// zero under steady load.
#[derive(Debug, Default)]
pub struct RoundArena {
    plan: RoundPlan,
    launches_cap: usize,
    lane_of_cap: usize,
    warmed: bool,
    grows: u64,
}

impl RoundArena {
    /// Reset the recycled plan for a new round and hand it out.
    pub fn begin(&mut self) -> &mut RoundPlan {
        self.plan.launches.clear();
        self.plan.lane_of.clear();
        self.plan.n_lanes = 0;
        self.plan.drained = 0;
        self.plan.deadline_splits = 0;
        &mut self.plan
    }

    /// Account this round's buffer capacities. The first round warms the
    /// arena; any later growth increments the counter.
    pub fn finish(&mut self) {
        let lc = self.plan.launches.capacity();
        let oc = self.plan.lane_of.capacity();
        if self.warmed && (lc > self.launches_cap || oc > self.lane_of_cap) {
            self.grows += 1;
        }
        self.launches_cap = self.launches_cap.max(lc);
        self.lane_of_cap = self.lane_of_cap.max(oc);
        self.warmed = true;
    }

    /// Buffer growths after warmup (0 == the round hot path reused its
    /// arena without heap growth).
    pub fn grows(&self) -> u64 {
        self.grows
    }
}

/// Lock-free mirror of the counters `snapshot()` reads: per-lane
/// launch/busy totals and the cost model's calibration errors, updated by
/// the driver at completion processing. Status polling reads these
/// atomics instead of locking the shard's cost model or walking its lane
/// tracks — a snapshot can never stall planning or execution, whichever
/// thread it runs on.
///
/// **Consistency (seqlock).** The pre-seqlock mirror published each word
/// as an independent relaxed atomic, so a poller could observe a torn
/// multi-word pair — e.g. a lane's `launches` incremented by a completion
/// whose `busy_ns` it hadn't seen yet. Every `record_*` now runs inside a
/// version window ([`SnapshotMirror::begin_write`] /
/// [`SnapshotMirror::end_write`]: `seq` odd while writing, even once
/// published) and [`SnapshotMirror::read`] retries until it sees one even
/// version across the whole multi-word read — the classic single-writer
/// seqlock (Boehm, "Can seqlocks get along with programming language
/// memory models?"). The word stores/loads themselves stay `Relaxed`; the
/// fences on the version counter carry all required ordering, and each
/// non-`Relaxed` site documents its ordering inline (enforced by
/// `cargo run -p xtask -- lint`).
///
/// **Single writer by construction:** only the shard's driver thread
/// calls `record_*` (from `process_completion`); the unsynchronized
/// read-modify-write of `seq` in the write path relies on that.
#[derive(Debug)]
struct SnapshotMirror {
    /// Seqlock version: odd while a write window is open, even when the
    /// mirror is consistent.
    seq: AtomicU64,
    /// EWMA relative prediction error, as f64 bits.
    calib_err: AtomicU64,
    lane_launches: Vec<AtomicU64>,
    /// Busy time per lane in nanoseconds.
    lane_busy_ns: Vec<AtomicU64>,
    /// Items stolen BY each lane (thief-side attribution).
    lane_steals: Vec<AtomicU64>,
    /// Per-lane-count calibration error, f64 bits, indexed by concurrent
    /// lane count; [`UNOBSERVED`] until that count has been measured.
    lane_calib: Vec<AtomicU64>,
}

const UNOBSERVED: u64 = u64::MAX;

/// One consistent cut of a [`SnapshotMirror`].
#[derive(Debug, Clone)]
struct MirrorView {
    calib_err: f64,
    lane_launches: Vec<u64>,
    lane_busy_s: Vec<f64>,
    lane_steals: Vec<u64>,
    lane_calibration: Vec<(usize, f64)>,
}

impl SnapshotMirror {
    fn new(lanes: usize) -> Self {
        Self {
            seq: AtomicU64::new(0),
            calib_err: AtomicU64::new(0.0f64.to_bits()),
            lane_launches: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            lane_busy_ns: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            lane_steals: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            lane_calib: (0..=lanes).map(|_| AtomicU64::new(UNOBSERVED)).collect(),
        }
    }

    /// Open a write window (version goes odd). Driver thread only.
    fn begin_write(&self) {
        let s = self.seq.load(Ordering::Relaxed);
        self.seq.store(s.wrapping_add(1), Ordering::Relaxed);
        // ordering: Release fence — pairs with the reader's Acquire fence:
        // any reader that observes a data store from this window will also
        // observe the odd version when it re-checks `seq`, and retry.
        std::sync::atomic::fence(Ordering::Release);
    }

    /// Close the write window (version returns to even).
    fn end_write(&self) {
        let s = self.seq.load(Ordering::Relaxed);
        // ordering: Release store — publishes the even version only after
        // every data store in the window is visible (pairs with the
        // reader's Acquire load of `seq`).
        self.seq.store(s.wrapping_add(1), Ordering::Release);
    }

    fn record_launch(&self, lane: usize, busy_s: f64) {
        let lane = lane.min(self.lane_launches.len().saturating_sub(1));
        self.begin_write();
        self.lane_launches[lane].fetch_add(1, Ordering::Relaxed);
        self.lane_busy_ns[lane]
            .fetch_add((busy_s.max(0.0) * 1e9) as u64, Ordering::Relaxed);
        self.end_write();
    }

    /// Count one steal executed BY `lane` (the thief). Driver thread only,
    /// at completion processing — same single-writer discipline as
    /// [`SnapshotMirror::record_launch`].
    fn record_steal(&self, lane: usize) {
        let lane = lane.min(self.lane_steals.len().saturating_sub(1));
        self.begin_write();
        self.lane_steals[lane].fetch_add(1, Ordering::Relaxed);
        self.end_write();
    }

    fn record_calibration(&self, err: f64) {
        self.begin_write();
        self.calib_err.store(err.to_bits(), Ordering::Relaxed);
        self.end_write();
    }

    fn record_lane_calibration(&self, lanes: usize, err: f64) {
        // Only overlapped counts (>= 2) appear in the per-lane table; the
        // solo error is `calib_err`.
        if lanes >= 2 && lanes < self.lane_calib.len() {
            self.begin_write();
            self.lane_calib[lanes].store(err.to_bits(), Ordering::Relaxed);
            self.end_write();
        }
    }

    /// One consistent multi-word snapshot. Retries while a write window
    /// is open or raced the read; bounded so a wedged writer can never
    /// spin a status poller forever (after the cap the last — possibly
    /// inconsistent — view is returned, which polling tolerates).
    fn read(&self) -> MirrorView {
        for _ in 0..1024 {
            // ordering: Acquire load — the data reads below must not be
            // hoisted above this version check (pairs with end_write's
            // Release store).
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 % 2 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let view = self.read_unchecked();
            // ordering: Acquire fence — the data reads above complete
            // before the version re-check below (pairs with begin_write's
            // Release fence).
            std::sync::atomic::fence(Ordering::Acquire);
            let s2 = self.seq.load(Ordering::Relaxed);
            if s1 == s2 {
                return view;
            }
        }
        self.read_unchecked()
    }

    /// Raw multi-word read with no version discipline — only meaningful
    /// under [`SnapshotMirror::read`]'s retry loop.
    fn read_unchecked(&self) -> MirrorView {
        MirrorView {
            calib_err: f64::from_bits(self.calib_err.load(Ordering::Relaxed)),
            lane_launches: self
                .lane_launches
                .iter()
                .map(|a| a.load(Ordering::Relaxed))
                .collect(),
            lane_busy_s: self
                .lane_busy_ns
                .iter()
                .map(|a| a.load(Ordering::Relaxed) as f64 / 1e9)
                .collect(),
            lane_steals: self
                .lane_steals
                .iter()
                .map(|a| a.load(Ordering::Relaxed))
                .collect(),
            lane_calibration: self
                .lane_calib
                .iter()
                .enumerate()
                .filter_map(|(l, a)| {
                    let bits = a.load(Ordering::Relaxed);
                    if bits == UNOBSERVED {
                        None
                    } else {
                        Some((l, f64::from_bits(bits)))
                    }
                })
                .collect(),
        }
    }

    fn calibration_error(&self) -> f64 {
        self.read().calib_err
    }

    fn lane_launches(&self) -> Vec<u64> {
        self.read().lane_launches
    }

    fn lane_busy_s(&self) -> Vec<f64> {
        self.read().lane_busy_s
    }

    fn lane_calibration(&self) -> Vec<(usize, f64)> {
        self.read().lane_calibration
    }

    #[cfg(test)]
    fn lane_steals(&self) -> Vec<u64> {
        self.read().lane_steals
    }
}

/// A dispatched round a shard has not fully collected yet.
#[derive(Debug)]
struct RoundTicket {
    round: u64,
    outstanding: usize,
}

/// One device shard: its own admission queues, scheduler instance,
/// persistent lane workers, fusion cache, and lifetime counters.
struct DeviceShard {
    queues: QueueSet,
    scheduler: Box<dyn Scheduler>,
    /// Launch-latency predictor for this device (Some iff EDF planning or
    /// multi-lane execution is on): shared with the shard's scheduler, fed
    /// by measured launch durations as completions are collected.
    cost_model: Option<SharedCostModel>,
    /// Persistent per-lane workers (spawned once, joined on drop).
    pool: LanePool,
    /// Rounds dispatched to the pool but not yet fully collected, oldest
    /// first.
    tickets: VecDeque<RoundTicket>,
    /// Device-resident stacked weight operands. Per shard: placement
    /// keeps tenants device-disjoint, so cache keys never span shards and
    /// shards never contend on each other's weight marshaling.
    fusion_cache: Mutex<FusionCache>,
    arena: RoundArena,
    mirror: SnapshotMirror,
    launches: u64,
    superkernel_launches: u64,
    drained: u64,
    /// Fused launches the EDF planner split to protect a deadline.
    deadline_splits: u64,
    flops: f64,
    /// Adaptive space-time controller (Some iff `[controller] adaptive`
    /// and the space-time scheduler): re-decides (lanes, depth) every
    /// dwell window from this shard's observed signals.
    controller: Option<AdaptiveController>,
    /// Round-level signal EWMAs feeding the controller (only updated when
    /// a controller is attached, so `adaptive = false` runs the exact
    /// static code path).
    tracker: SignalTracker,
    /// Lanes currently resident (== pool width; static `lanes` when the
    /// controller is off).
    resident_lanes: usize,
    /// Effective pipeline depth (static `pipeline_depth` when off).
    resident_depth: usize,
    /// Deadline verdicts since the controller's last decision point (the
    /// windowed attainment signal; reset at each evaluation).
    win_hits: u64,
    win_misses: u64,
    /// Completions and stolen completions since the controller's last
    /// decision point — their ratio is the steal-rate imbalance signal
    /// (reset at each evaluation, like the attainment window).
    win_launches: u64,
    win_steals: u64,
    /// Failed launches re-dispatched once onto another lane (lifetime).
    launch_retries: u64,
}

/// The coordinator.
pub struct Coordinator {
    engine: Arc<PjrtEngine>,
    pub tenants: TenantRegistry,
    /// Metric handles interned by tenant id at construction — the hot
    /// path never does a name lookup or clones a `String` per event.
    tenant_metrics: Vec<Arc<TenantMetrics>>,
    shards: Vec<DeviceShard>,
    placer: DevicePlacer<ShapeClass>,
    /// Global admission cap across all shards.
    queue_cap: usize,
    /// Deadline-aware (EDF) planning on (space-time only).
    edf: bool,
    /// Spatial execution lanes per device (space-time only; 1 == one
    /// worker per shard, launches execute serially in plan order).
    lanes: usize,
    /// Rounds allowed in flight per shard: 1 == serial (collect each
    /// round before the next plan), 2 == plan/marshal round N+1 while
    /// round N executes.
    pipeline_depth: usize,
    /// Safety margin (seconds) for deadline budgets and admission checks.
    deadline_slack: f64,
    /// Requests judged deadline-infeasible at admission. Every
    /// `PROBE_EVERY`-th one is admitted anyway as a *probe*: its launch
    /// feeds a fresh measurement back to the cost model, so a predictor
    /// inflated by one anomalously slow launch cannot lock a class out
    /// forever (no launches → no observations → no recovery).
    infeasible_seen: u64,
    flavor: Flavor,
    monitor: SloMonitor,
    pub metrics: Arc<MetricsRegistry>,
    next_id: RequestId,
    rounds_since_check: u32,
    /// Monitor window length, in scheduling rounds.
    check_every: u32,
    /// Lifetime round counter (drives round tags and the solo-calibration
    /// probe cadence).
    rounds_total: u64,
    /// Cross-lane work stealing on (`[server] steal`; space-time only).
    /// Stealing is suspended around solo-calibration probe rounds and
    /// re-enabled from this flag afterwards.
    steal: bool,
    started: Instant,
}

/// With `lanes > 1`, every `SOLO_PROBE_EVERY`-th round executes serially
/// even when the plan spans several lanes: overlapped measurements alone
/// cannot disentangle solo latency from the interference stretch (the
/// stretch EWMA would absorb any solo-track bias forever), so the solo
/// track needs periodic un-overlapped ground truth — the same recovery
/// valve pattern as the admission probe (`PROBE_EVERY`).
const SOLO_PROBE_EVERY: u64 = 32;

impl Coordinator {
    /// Build from config: loads the manifest, registers tenants, places
    /// them on the device pool, picks the scheduler, spawns the per-shard
    /// lane workers, and pre-warms the executables the workload will need.
    pub fn new(cfg: &ServerConfig) -> Result<Self> {
        Self::with_flavor(cfg, Flavor::Xla)
    }

    pub fn with_flavor(cfg: &ServerConfig, flavor: Flavor) -> Result<Self> {
        Self::with_flavor_wrapped(cfg, flavor, &|exec| exec)
    }

    /// [`Coordinator::with_flavor`] with an executor wrapper: `wrap`
    /// receives the real PJRT executor and may interpose on it — the
    /// fault-injection hook the launch-retry regression tests use to make
    /// a specific launch fail without touching the PJRT layer. Production
    /// paths pass the identity wrapper via `with_flavor`.
    pub fn with_flavor_wrapped(
        cfg: &ServerConfig,
        flavor: Flavor,
        wrap: &dyn Fn(Arc<dyn LaunchExecutor>) -> Arc<dyn LaunchExecutor>,
    ) -> Result<Self> {
        let engine = Arc::new(PjrtEngine::new(&cfg.artifacts_dir)?);
        let tenants = TenantRegistry::from_configs(&cfg.tenants)
            .map_err(|e| anyhow::anyhow!(e))?;
        // R buckets from the manifest (all kinds share aot.py's bucket set).
        let mut buckets = engine.manifest().r_buckets("batched_gemm", flavor.as_str());
        if buckets.is_empty() {
            buckets = vec![1];
        }
        // Fail fast: every tenant's shape class must have lowered artifacts
        // (the catalog is fixed at `make artifacts` time).
        for t in tenants.iter() {
            let class = t.spec.shape_class();
            let servable = engine
                .manifest()
                .find(class.kind, flavor.as_str(), class.mnk(), buckets[0])
                .or_else(|| {
                    if class.kind == "batched_gemm" {
                        None
                    } else {
                        engine.manifest().find(class.kind, flavor.as_str(), (0, 0, 0), buckets[0])
                    }
                })
                .is_some();
            if !servable {
                return Err(anyhow::anyhow!(
                    "tenant {}: no AOT artifact for shape class {class} \
                     (lowered classes are fixed at `make artifacts` time)",
                    t.name
                ));
            }
        }
        let policy = if cfg.split_exact {
            crate::coordinator::batcher::PaddingPolicy::SplitExact
        } else {
            crate::coordinator::batcher::PaddingPolicy::PadToBucket
        };
        // Place tenants on the device pool: least-loaded, class-affine
        // (load weight = per-request FLOPs of the tenant's shape class).
        let devices = cfg.devices.max(1);
        let tenant_classes: Vec<_> = tenants
            .iter()
            .map(|t| {
                let class = t.spec.shape_class();
                (class, class.flops())
            })
            .collect();
        let placer = DevicePlacer::new(&tenant_classes, devices);
        // Per-shard queues enforce only the per-tenant depth; the pool-wide
        // `queue_cap` spans shards, so `submit` enforces it and records
        // sheds on the target shard's QueueSet counter.
        //
        // Each shard's QueueSet is indexed by GLOBAL tenant id (O(devices x
        // tenants) queue slots, most permanently empty). That keeps the
        // schedulers device-blind — no id remapping between shards and
        // launch entries — at the cost of per-round backlogged() scans over
        // empty queues; compact per-shard id maps are a follow-up if tenant
        // counts grow past the low hundreds.
        // Deadline-aware (EDF) planning and spatial lanes only apply to the
        // space-time scheduler; each shard gets its own cost model so
        // calibration follows the device the launches actually ran on. The
        // cost model exists whenever lanes > 1 too — multi-lane rounds need
        // it for makespan balancing and the co-location interference term
        // even without EDF.
        let spacetime = cfg.scheduler == crate::config::SchedulerKind::SpaceTime;
        let edf = cfg.edf && spacetime;
        let lanes = if spacetime { cfg.lanes.max(1) } else { 1 };
        let pipeline_depth = cfg.pipeline_depth.max(1);
        // Adaptive space-time control only applies to the space-time
        // scheduler (the §3 baselines stay exactly the paper's policies).
        // The controller's caps resolve against the static knobs; the pool
        // starts at the static lane count and the controller reconfigures
        // from there. With `adaptive = false` nothing below changes:
        // resident == static, no controller, no tracker feeding.
        let adaptive = cfg.controller.adaptive && spacetime;
        // Cross-lane work stealing only means anything under the
        // space-time scheduler (the §3 baselines stay the paper's
        // policies); with one static lane it is a harmless no-op, but the
        // adaptive controller may grow lanes later, so gate on the config
        // + scheduler only.
        let steal = cfg.steal && spacetime;
        let ctrl_max_lanes = cfg.controller.max_lanes_or(lanes);
        let ctrl_max_depth = cfg.controller.max_depth_or(pipeline_depth);
        let (init_lanes, init_depth, lanes_cap) = if adaptive {
            (
                lanes.clamp(1, ctrl_max_lanes),
                pipeline_depth.clamp(1, ctrl_max_depth),
                lanes.max(ctrl_max_lanes),
            )
        } else {
            (lanes, pipeline_depth, lanes)
        };
        let executor: Arc<dyn LaunchExecutor> =
            wrap(Arc::new(PjrtExecutor::new(engine.clone(), flavor)));
        let shards = (0..devices)
            .map(|_| {
                let cost_model: Option<SharedCostModel> =
                    if edf || lanes > 1 || adaptive {
                        Some(Arc::new(Mutex::new(CostModel::new())))
                    } else {
                        None
                    };
                let mut scheduler = crate::coordinator::scheduler::make_scheduler_spatial(
                    cfg.scheduler,
                    buckets.clone(),
                    cfg.max_batch as usize,
                    policy,
                    cfg.slo_aware,
                    init_lanes,
                    cost_model.clone(),
                    if edf { Some(cfg.deadline_slack) } else { None },
                );
                scheduler.set_steal_aware(steal);
                let mut pool = LanePool::new(init_lanes, executor.clone());
                pool.set_steal(steal);
                pool.set_steal_min(cfg.steal_min_queue);
                let controller = if adaptive {
                    Some(AdaptiveController::new(
                        ControllerParams {
                            max_lanes: ctrl_max_lanes,
                            max_depth: ctrl_max_depth,
                            dwell_rounds: cfg.controller.dwell_rounds,
                            improvement: cfg.controller.improvement,
                            slo_target: cfg.controller.slo_target,
                        },
                        Decision { lanes: init_lanes, depth: init_depth },
                    ))
                } else {
                    None
                };
                DeviceShard {
                    queues: QueueSet::new(tenants.len(), cfg.queue_depth),
                    scheduler,
                    cost_model,
                    pool,
                    tickets: VecDeque::new(),
                    fusion_cache: Mutex::new(FusionCache::new(256)),
                    arena: RoundArena::default(),
                    mirror: SnapshotMirror::new(lanes_cap),
                    launches: 0,
                    superkernel_launches: 0,
                    drained: 0,
                    deadline_splits: 0,
                    flops: 0.0,
                    controller,
                    tracker: SignalTracker::default(),
                    resident_lanes: init_lanes,
                    resident_depth: init_depth,
                    win_hits: 0,
                    win_misses: 0,
                    win_launches: 0,
                    win_steals: 0,
                    launch_retries: 0,
                }
            })
            .collect();
        let device_map: Vec<usize> =
            (0..tenants.len()).map(|t| placer.device_of(t)).collect();
        let monitor = SloMonitor::new(
            MonitorConfig {
                enabled: cfg.eviction_enabled,
                threshold: cfg.eviction_threshold,
                strikes: cfg.eviction_strikes,
                ..Default::default()
            },
            &tenants,
        )
        .with_device_map(device_map);
        let metrics = Arc::new(MetricsRegistry::new());
        let tenant_metrics: Vec<Arc<TenantMetrics>> =
            tenants.iter().map(|t| metrics.tenant(&t.name)).collect();
        Ok(Self {
            engine,
            tenants,
            tenant_metrics,
            shards,
            placer,
            queue_cap: cfg.queue_cap,
            edf,
            lanes,
            pipeline_depth: cfg.pipeline_depth.max(1),
            deadline_slack: cfg.deadline_slack.max(0.0),
            infeasible_seen: 0,
            flavor,
            monitor,
            metrics,
            next_id: 0,
            rounds_since_check: 0,
            check_every: 16,
            rounds_total: 0,
            steal,
            started: Instant::now(),
        })
    }

    pub fn engine(&self) -> &Arc<PjrtEngine> {
        &self.engine
    }

    pub fn scheduler_label(&self) -> &'static str {
        self.shards[0].scheduler.label()
    }

    /// Devices in the pool.
    pub fn devices(&self) -> usize {
        self.shards.len()
    }

    /// Which device a tenant's requests execute on.
    pub fn device_of(&self, tenant: usize) -> usize {
        self.placer.device_of(tenant)
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Whether deadline-aware (EDF) planning is active.
    pub fn deadline_aware(&self) -> bool {
        self.edf
    }

    /// Spatial execution lanes per device (1 == serial rounds).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Rounds allowed in flight per shard (1 == serial round loop). The
    /// configured static value; with the adaptive controller on, the
    /// effective per-shard depth is [`Coordinator::resident`].
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Whether the adaptive space-time controller is driving (lanes,
    /// depth) online.
    pub fn adaptive(&self) -> bool {
        self.shards.iter().any(|s| s.controller.is_some())
    }

    /// The (resident lanes, effective depth) operating point of one shard
    /// right now — the adaptive controller's current decision, or the
    /// static knobs when it is off. None for an unknown device.
    pub fn resident(&self, device: usize) -> Option<(usize, usize)> {
        self.shards
            .get(device)
            .map(|s| (s.resident_lanes, s.resident_depth))
    }

    /// Rounds dispatched to lane workers but not yet fully collected,
    /// summed across shards. Drain loops run until this AND `pending()`
    /// are both zero.
    pub fn in_flight_rounds(&self) -> usize {
        self.shards.iter().map(|s| s.tickets.len()).sum()
    }

    /// Round-arena buffer growths after warmup, summed across shards
    /// (0 == the hot path recycled its buffers without heap growth).
    pub fn arena_grows(&self) -> u64 {
        self.shards.iter().map(|s| s.arena.grows()).sum()
    }

    /// The launch-latency predictor of one device shard (None when EDF
    /// planning is off or the device is unknown).
    pub fn cost_model(&self, device: usize) -> Option<&SharedCostModel> {
        self.shards.get(device).and_then(|s| s.cost_model.as_ref())
    }

    /// Requests shed by the global admission cap over the lifetime.
    pub fn shed_total(&self) -> u64 {
        self.shards.iter().map(|s| s.queues.shed).sum()
    }

    /// Batcher statistics summed across the pool (None for non-batching
    /// schedulers).
    pub fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        let mut merged: Option<crate::coordinator::batcher::BatcherStats> = None;
        for shard in &self.shards {
            if let Some(bs) = shard.scheduler.batcher_stats() {
                let m = merged.get_or_insert_with(Default::default);
                m.launches += bs.launches;
                m.problems += bs.problems;
                m.padded_lanes += bs.padded_lanes;
            }
        }
        merged
    }

    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.queues.total_pending()).sum()
    }

    /// Per-device counters (index == device id). Reads the atomic
    /// snapshot mirrors — never locks a cost model, so status polling
    /// cannot stall planning or lane workers mid-round.
    pub fn device_snapshots(&self) -> Vec<DeviceSnapshot> {
        self.shards
            .iter()
            .enumerate()
            .map(|(d, s)| {
                let cache = lock_recover(&s.fusion_cache);
                // One seqlock-consistent cut across every mirror word —
                // per-lane busy/launch pairs can't tear across fields.
                let mirror = s.mirror.read();
                DeviceSnapshot {
                    device: d,
                    tenants: self.placer.members(d).len() as u64,
                    pending: s.queues.total_pending() as u64,
                    launches: s.launches,
                    superkernel_launches: s.superkernel_launches,
                    drained: s.drained,
                    shed: s.queues.shed,
                    deadline_splits: s.deadline_splits,
                    cost_calibration_error: mirror.calib_err,
                    lane_launches: mirror.lane_launches,
                    lane_busy_s: mirror.lane_busy_s,
                    lane_calibration: mirror.lane_calibration,
                    lane_steals: mirror.lane_steals,
                    launch_retries: s.launch_retries,
                    ctrl_adaptive: s.controller.is_some(),
                    ctrl_lanes: s.resident_lanes as u64,
                    ctrl_depth: s.resident_depth as u64,
                    ctrl_reconfigs: s.controller.as_ref().map_or(0, |c| c.reconfigs()),
                    ctrl_evals: s.controller.as_ref().map_or(0, |c| c.evals()),
                    ctrl_utility: s.controller.as_ref().map_or(0.0, |c| c.last_utility()),
                    ctrl_utilities: s
                        .controller
                        .as_ref()
                        .map_or_else(Vec::new, |c| c.last_utilities().to_vec()),
                    cache_hits: cache.stats.hits,
                    cache_misses: cache.stats.misses,
                    cache_evictions: cache.stats.evictions,
                    cache_resident: cache.len() as u64,
                    flops: s.flops,
                }
            })
            .collect()
    }

    /// Pre-compile every executable this coordinator's tenants can hit, so
    /// the serving path never compiles.
    pub fn warmup(&self) -> Result<usize> {
        let kinds: std::collections::BTreeSet<&'static str> = self
            .tenants
            .iter()
            .map(|t| t.spec.shape_class().kind)
            .collect();
        let flavor = self.flavor.as_str();
        Ok(self.engine.warmup(|a| {
            a.impl_ == flavor && kinds.contains(a.kind.as_str())
        })?)
    }

    /// Intern metric handles for tenants registered after construction
    /// (`tenants` is public and `TenantRegistry::register` is callable):
    /// the hot path indexes `tenant_metrics` by id, so the vector must
    /// cover the whole registry. One length comparison when nothing
    /// changed.
    fn intern_tenant_metrics(&mut self) {
        for t in self.tenant_metrics.len()..self.tenants.len() {
            let handle = self
                .metrics
                .tenant(&self.tenants.get(t).expect("registry is index-dense").name);
            self.tenant_metrics.push(handle);
        }
    }

    /// Submit a request for `tenant` with the given payload tensors — the
    /// deprecation-path signature: builds a default [`RequestContext`]
    /// (SLO-default deadline, normal priority) and delegates to
    /// [`Coordinator::submit_ctx`]. New callers should build a context.
    pub fn submit(
        &mut self,
        tenant: usize,
        payload: Vec<HostTensor>,
    ) -> Result<RequestId, Reject> {
        self.submit_ctx(RequestContext::new(tenant), payload)
    }

    /// Submit a request described by a full [`RequestContext`]: the
    /// context's deadline (wire-supplied absolute instant or budget, or
    /// the tenant SLO as the explicit default) is the deadline the EDF
    /// queues order by — admission does not re-derive it from config.
    ///
    /// Admission is bounded twice: a global cap across the pool
    /// ([`Reject::Overloaded`], 429-style shed) and the per-tenant queue
    /// depth ([`Reject::QueueFull`]). With EDF on, a request whose
    /// context deadline is already infeasible sheds with
    /// [`Reject::DeadlineInfeasible`].
    pub fn submit_ctx(
        &mut self,
        ctx: RequestContext,
        payload: Vec<HostTensor>,
    ) -> Result<RequestId, Reject> {
        self.intern_tenant_metrics();
        let tenant = ctx.tenant;
        let t = self
            .tenants
            .get(tenant)
            .ok_or_else(|| Reject::BadRequest(format!("unknown tenant {tenant}")))?;
        if !t.is_servable() {
            self.tenant_metrics[tenant].record_rejection();
            return Err(Reject::TenantEvicted);
        }
        let shapes = t.spec.payload_shapes();
        if payload.len() != shapes.len() {
            return Err(Reject::BadRequest(format!(
                "expected {} payload tensors, got {}",
                shapes.len(),
                payload.len()
            )));
        }
        for (p, want) in payload.iter().zip(&shapes) {
            if &p.shape != want {
                return Err(Reject::BadRequest(format!(
                    "payload shape {:?} != expected {:?}",
                    p.shape, want
                )));
            }
        }
        let slo = std::time::Duration::from_secs_f64(t.slo_ms / 1e3);
        let class = t.spec.shape_class();
        let device = self.placer.device_of(tenant);
        let arrived = Instant::now();
        // Deadline-aware admission: a request whose *minimal immediate*
        // launch is already predicted past its deadline is lost no matter
        // what the planner does — shed it now (504-style) instead of
        // queueing doomed work (DARIS, arXiv:2504.08795). The budget is
        // the CONTEXT's remaining time, so a client-tightened deadline
        // sheds earlier and a client-relaxed one admits more — config is
        // no longer the arbiter.
        if self.edf {
            if let Some(cm) = &self.shards[device].cost_model {
                let budget_s = ctx
                    .resolve_deadline(arrived, slo)
                    .saturating_duration_since(arrived)
                    .as_secs_f64();
                let infeasible = lock_recover(cm)
                    .deadline_infeasible(class, budget_s, self.deadline_slack);
                if infeasible {
                    self.infeasible_seen += 1;
                    // Recovery valve: admit every PROBE_EVERY-th infeasible
                    // request so its measured launch can deflate a predictor
                    // stuck high (see `infeasible_seen`). The probe at worst
                    // misses its deadline — which is counted, not hidden.
                    const PROBE_EVERY: u64 = 16;
                    if self.infeasible_seen % PROBE_EVERY != 0 {
                        // The shed request is still offered load: keep the
                        // shard's arrival-rate estimate truthful.
                        self.shards[device].queues.note_arrival(arrived);
                        self.tenant_metrics[tenant].record_rejection();
                        return Err(Reject::DeadlineInfeasible);
                    }
                }
            }
        }
        // Global admission cap across every shard: shed, don't grow (the
        // shed still counts toward the shard's offered-load estimate).
        if self.pending() >= self.queue_cap {
            self.shards[device].queues.record_shed_at(arrived);
            self.tenant_metrics[tenant].record_rejection();
            return Err(Reject::Overloaded);
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = ctx.into_request(id, class, payload, arrived, slo);
        match self.shards[device].queues.push(req) {
            Ok(()) => Ok(id),
            Err(rej) => {
                self.tenant_metrics[tenant].record_rejection();
                Err(rej)
            }
        }
    }

    /// Synthesize a random request payload for a tenant (tests/benches).
    pub fn random_payload(&self, tenant: usize, rng: &mut Rng) -> Vec<HostTensor> {
        self.tenants
            .get(tenant)
            .map(|t| {
                t.spec
                    .payload_shapes()
                    .iter()
                    .map(|s| HostTensor::random(s, rng))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Run one pipelined scheduling round: per device shard, plan round
    /// N+1 and dispatch it to the persistent lane workers (pre-marshaling
    /// weights through the shard's fusion cache — the expensive upload
    /// overlaps round N's execution), then collect completions until at
    /// most `pipeline_depth - 1` rounds remain in flight. Responses in
    /// the outcome come from the collected round(s); see [`RoundOutcome`].
    pub fn run_round(&mut self) -> Result<RoundOutcome> {
        let mut outcome = RoundOutcome {
            launches_per_device: vec![0; self.shards.len()],
            ..Default::default()
        };
        self.rounds_total += 1;
        let round = self.rounds_total;
        let probe_solo = self.rounds_total % SOLO_PROBE_EVERY == 0
            && self.shards.iter().any(|s| s.resident_lanes > 1);
        if probe_solo && self.steal {
            // Suspend stealing for the probe window: a thief lane pulling
            // the probe's queued launches would re-overlap exactly the
            // execution the solo-calibration track must measure
            // un-overlapped. Restored below even on an error path.
            for s in &mut self.shards {
                s.pool.set_steal(false);
            }
        }
        let phases = self.run_round_phases(round, probe_solo, &mut outcome);
        if probe_solo && self.steal {
            for s in &mut self.shards {
                s.pool.set_steal(true);
            }
        }
        phases?;
        // Periodic straggler check (stragglers judged against same-device
        // peers — see SloMonitor::with_device_map).
        self.rounds_since_check += 1;
        if self.rounds_since_check >= self.check_every {
            self.rounds_since_check = 0;
            let evictions = self.monitor.check(&mut self.tenants);
            for ev in &evictions {
                self.tenant_metrics[ev.tenant].record_eviction();
                // Drop the evicted tenant's device-resident weights, fail
                // everything it still has queued, and release its load
                // from the placement accounting (a later re-registration
                // re-joins its class via `DevicePlacer::readmit`).
                let device = self.placer.device_of(ev.tenant);
                lock_recover(&self.shards[device].fusion_cache)
                    .invalidate_tenant(ev.tenant);
                for req in self.shards[device].queues.drain_tenant(ev.tenant) {
                    outcome.rejections.push((req.id, Reject::TenantEvicted));
                }
                self.placer.release(ev.tenant);
            }
            outcome.evictions = evictions;
        }
        Ok(outcome)
    }

    /// The dispatch/collect body of [`Coordinator::run_round`], split out
    /// so the probe-window steal suspension around it restores on every
    /// exit path.
    fn run_round_phases(
        &mut self,
        round: u64,
        probe_solo: bool,
        outcome: &mut RoundOutcome,
    ) -> Result<()> {
        if probe_solo {
            // A solo probe's measurements must be genuinely un-overlapped
            // or they would pollute the solo track with interference from
            // rounds still executing: drain EVERY shard first (they share
            // one underlying engine, so even another shard's in-flight
            // round would contend), and below each shard's probe is
            // collected before the next dispatches — a deliberate
            // pipeline bubble once every SOLO_PROBE_EVERY rounds.
            for device in 0..self.shards.len() {
                self.collect_rounds(device, 0, outcome)?;
            }
        }
        for device in 0..self.shards.len() {
            let dispatched = self.dispatch_round(device, round, probe_solo, outcome)?;
            // With nothing new dispatched (idle shard) there is nothing to
            // overlap with: collect every outstanding round so responses
            // are never held hostage to a lull in arrivals.
            let allowed = if dispatched && !probe_solo {
                // Effective depth is per shard: the adaptive controller
                // may have chosen a shallower pipeline than configured.
                self.shards[device].resident_depth - 1
            } else {
                0
            };
            self.collect_rounds(device, allowed, outcome)?;
        }
        Ok(())
    }

    /// Plan one shard's round in its recycled arena and dispatch every
    /// launch to the lane workers, resolving weight operands through the
    /// shard's fusion cache at dispatch time. Returns whether anything
    /// was dispatched.
    // lint: hot-path
    fn dispatch_round(
        &mut self,
        device: usize,
        round: u64,
        probe_solo: bool,
        outcome: &mut RoundOutcome,
    ) -> Result<bool> {
        let now = Instant::now();
        self.control_round(device, now);
        let shard = &mut self.shards[device];
        let plan_t0 = Instant::now();
        let plan = shard.arena.begin();
        shard.scheduler.plan_round_into(&mut shard.queues, now, plan);
        let planned = plan.launches.len();
        let drained = plan.drained;
        outcome.launches += planned;
        outcome.launches_per_device[device] = planned;
        shard.launches += planned as u64;
        shard.drained += plan.drained as u64;
        shard.deadline_splits += plan.deadline_splits as u64;
        if planned == 0 {
            shard.arena.finish();
            return Ok(false);
        }
        // The round tag: how many lanes this round keeps concurrently
        // resident (1 on a solo-calibration probe round, which routes the
        // whole plan through lane 0 so launches execute un-overlapped).
        let lanes_used = if probe_solo { 1 } else { plan.lanes_used() };
        let n_lanes = plan.n_lanes;
        let (hits_before, misses_before) = {
            let c = lock_recover(&shard.fusion_cache);
            (c.stats.hits, c.stats.misses)
        };
        let lane_of = std::mem::take(&mut plan.lane_of);
        let cost_of = std::mem::take(&mut plan.cost_of);
        let mut sent = 0usize;
        let mut dispatch_err = None;
        for (index, launch) in plan.launches.drain(..).enumerate() {
            let Some(first) = launch.entries.first() else { continue };
            // lint: allow(hot-path-alloc) — `ModelSpec` is a plain-data
            // enum, so this clone is a few-word copy with no heap
            // allocation; it rides the WorkItem so the lane worker never
            // touches the tenant registry.
            let spec = self
                .tenants
                .get(first.tenant)
                .expect("launch entries reference registered tenants")
                .spec
                .clone();
            let lane = if probe_solo || n_lanes <= 1 {
                0
            } else {
                lane_of
                    .get(index)
                    .copied()
                    .unwrap_or(0)
                    .min(shard.pool.lanes().saturating_sub(1))
            };
            // Marshal the weight operands NOW, on the driver thread: on a
            // cache hit this is a map lookup; on a miss the host gather +
            // device upload overlaps the previous round still executing on
            // the lane workers. The time spent rides the WorkItem so the
            // measurement fed back to the cost model still covers it.
            let marshal_t0 = Instant::now();
            match SuperKernelExec::resolve_weights(
                &self.engine,
                &launch,
                &self.tenants,
                &shard.fusion_cache,
            ) {
                Ok(weights) => {
                    shard.pool.dispatch(WorkItem {
                        round,
                        index,
                        lane,
                        lanes_resident: lanes_used,
                        launch,
                        spec,
                        weights,
                        weights_marshal_s: marshal_t0.elapsed().as_secs_f64(),
                        // Predicted cost from the balancer (0.0 when no
                        // cost model): the victim-selection heuristic
                        // ranks lanes by summed hints, so thieves steal
                        // from the predicted-longest backlog.
                        cost_hint: cost_of.get(index).copied().unwrap_or(0.0),
                        executed_lane: lane,
                        stolen: false,
                        attempt: 0,
                    });
                    sent += 1;
                }
                Err(e) => {
                    // Marshal failure aborts the rest of the plan (the
                    // engine is broken); launches already dispatched still
                    // complete and are collected normally.
                    dispatch_err = Some(e);
                    break;
                }
            }
        }
        plan.lane_of = lane_of;
        plan.cost_of = cost_of;
        shard.arena.finish();
        if shard.controller.is_some() {
            // Plan + marshal time is what a deeper pipeline hides; the
            // controller prices the depth choice against this EWMA.
            let plan_s = plan_t0.elapsed().as_secs_f64();
            shard.tracker.observe_round(planned, drained, plan_s);
        }
        if sent > 0 {
            shard.tickets.push_back(RoundTicket { round, outstanding: sent });
        }
        // Forward fusion-cache hit/miss deltas from this dispatch to the
        // global metrics (weight marshaling happens only here, so the
        // delta window is exact per round).
        {
            let c = lock_recover(&shard.fusion_cache);
            for _ in hits_before..c.stats.hits {
                self.metrics.record_cache(true);
            }
            for _ in misses_before..c.stats.misses {
                self.metrics.record_cache(false);
            }
        }
        if let Some(e) = dispatch_err {
            return Err(e);
        }
        Ok(sent > 0)
    }

    /// Adaptive-controller hook, run before each round is planned. Split
    /// into the cluster tier's two halves — [`Coordinator::plan_control`]
    /// (worker-side: gather signals, decide) and
    /// [`Coordinator::apply_control`] (committer-side: apply the decided
    /// operating point) — so a decision can be shipped across the
    /// sequencer→committer boundary and journaled before it takes effect.
    /// No-op when `adaptive = false`.
    fn control_round(&mut self, device: usize, now: Instant) {
        if let Some(plan) = self.plan_control(device, now) {
            self.apply_control(&plan);
        }
    }

    /// Worker-side half: count the round and, at each dwell boundary,
    /// gather this shard's signals (backlog + offered-load EWMA from its
    /// `QueueSet`, round/launch EWMAs from its tracker, calibrated
    /// interference stretch from its cost model, windowed deadline
    /// attainment, tightest tenant SLO) and let the controller re-decide
    /// (lanes, depth). Pure decision-making: nothing is reconfigured
    /// here. Returns `None` off the dwell boundary or when the shard is
    /// not adaptive.
    fn plan_control(&mut self, device: usize, now: Instant) -> Option<ControlPlan> {
        let due = match &mut self.shards[device].controller {
            Some(ctl) => ctl.tick(),
            None => return None,
        };
        if !due {
            return None;
        }
        // Tightest SLO among servable tenants placed on this shard — the
        // deadline budget candidate latencies must fit.
        let mut min_slo_s = f64::INFINITY;
        for t in self.placer.members(device) {
            if let Some(tn) = self.tenants.get(t) {
                if tn.is_servable() {
                    min_slo_s = min_slo_s.min(tn.slo_ms / 1e3);
                }
            }
        }
        if !min_slo_s.is_finite() {
            min_slo_s = 0.0; // no servable tenants: unconstrained
        }
        let shard = &mut self.shards[device];
        let ctl = shard.controller.as_mut().expect("due implies controller");
        let max_lanes = ctl.params().max_lanes;
        let stretch: Vec<f64> = match &shard.cost_model {
            Some(cm) => {
                let cm = lock_recover(cm);
                (0..=max_lanes).map(|n| cm.lane_stretch(n)).collect()
            }
            None => vec![1.0; max_lanes + 1],
        };
        // Windowed deadline attainment since the previous decision point
        // (None when no verdict landed this window).
        let win_total = shard.win_hits + shard.win_misses;
        let slo_attainment = if win_total == 0 {
            None
        } else {
            Some(shard.win_hits as f64 / win_total as f64)
        };
        // Fraction of this window's completions that executed on a thief
        // lane. 0.0 with stealing off (win_steals never increments), so
        // the signal is inert for non-stealing configs.
        let steal_rate = if shard.win_launches == 0 {
            0.0
        } else {
            shard.win_steals as f64 / shard.win_launches as f64
        };
        let signals = ControlSignals {
            backlog: shard.queues.total_pending(),
            arrival_rate: shard.queues.arrival_rate(now),
            launches_per_round: shard.tracker.launches_per_round(),
            requests_per_round: shard.tracker.requests_per_round(),
            mean_launch_s: shard.tracker.mean_launch_s(),
            plan_s: shard.tracker.plan_s(),
            stretch,
            slo_attainment,
            min_slo_s,
            steal_rate,
        };
        let decision = ctl.decide(&signals);
        // The window's verdicts are consumed at every dwell boundary: a
        // boundary with verdicts always evaluates (verdicts imply
        // completions, which imply the tracker signals decide() needs).
        shard.win_hits = 0;
        shard.win_misses = 0;
        shard.win_launches = 0;
        shard.win_steals = 0;
        Some(ControlPlan { device, decision })
    }

    /// Committer-side half: apply a decided operating point. A lane
    /// change resizes the persistent pool and re-targets the scheduler in
    /// place — the arena and scheduler scratch survive, so
    /// reconfiguration does not reintroduce hot-path allocation.
    fn apply_control(&mut self, plan: &ControlPlan) {
        let shard = &mut self.shards[plan.device];
        if plan.decision.lanes != shard.resident_lanes {
            shard.pool.resize(plan.decision.lanes);
            shard.scheduler.set_lanes(plan.decision.lanes);
            shard.resident_lanes = plan.decision.lanes;
        }
        shard.resident_depth = plan.decision.depth;
    }

    /// Collect completions for one shard until at most `allowed` rounds
    /// remain in flight, streaming each completion straight into the
    /// outcome (responses, metrics, monitor, cost-model feedback — all
    /// attributed via the completion's round tag).
    // lint: hot-path
    fn collect_rounds(
        &mut self,
        device: usize,
        allowed: usize,
        outcome: &mut RoundOutcome,
    ) -> Result<()> {
        while self.shards[device].tickets.len() > allowed {
            // lint: allow(hot-path-alloc) — `LanePool::collect` receives
            // one round-tagged completion from the channel; a name
            // collision with `Iterator::collect`, not an allocation.
            let completion = self.shards[device].pool.collect()?;
            self.process_completion(device, completion, outcome)?;
        }
        Ok(())
    }

    // lint: hot-path
    fn process_completion(
        &mut self,
        device: usize,
        c: Completion,
        outcome: &mut RoundOutcome,
    ) -> Result<()> {
        let shard = &mut self.shards[device];
        // Ticket bookkeeping first so an execution error cannot wedge the
        // in-flight accounting.
        if let Some(pos) = shard.tickets.iter().position(|t| t.round == c.round) {
            shard.tickets[pos].outstanding -= 1;
            if shard.tickets[pos].outstanding == 0 {
                let _ = shard.tickets.remove(pos);
            }
        }
        let res = match c.result {
            Ok(res) => res,
            Err(e) if c.attempt == 0 && shard.pool.lanes() > 1 => {
                // First failure with somewhere else to run: retry ONCE
                // through the steal path on the next lane over. The
                // completion carries launch/spec/weights exactly so this
                // rebuild needs no registry or fusion-cache access, and
                // the weights are already device-resident (marshal cost
                // was paid — and recorded — on the first attempt). The
                // round's ticket was decremented above, so re-open it for
                // the retried launch.
                let lanes = shard.pool.lanes();
                let target = (c.executed_lane + 1) % lanes;
                log::warn!(
                    "launch {} of round {} failed on lane {}: {e:#}; \
                     retrying once on lane {target}",
                    c.index,
                    c.round,
                    c.executed_lane
                );
                shard.launch_retries += 1;
                if let Some(pos) =
                    shard.tickets.iter().position(|t| t.round == c.round)
                {
                    shard.tickets[pos].outstanding += 1;
                } else {
                    shard
                        .tickets
                        .push_back(RoundTicket { round: c.round, outstanding: 1 });
                }
                shard.pool.dispatch(WorkItem {
                    round: c.round,
                    index: c.index,
                    // Queued on the NEXT lane over (the pool queues by
                    // `lane`); if that lane is also backed up, a thief can
                    // still pull it — the retry rides the steal machinery.
                    lane: target,
                    lanes_resident: c.lanes_resident,
                    launch: c.launch,
                    spec: c.spec,
                    weights: c.weights,
                    weights_marshal_s: 0.0,
                    cost_hint: c.cost_hint,
                    executed_lane: target,
                    stolen: false,
                    attempt: 1,
                });
                return Ok(());
            }
            Err(e) => {
                // A failed launch must not discard the outcome: responses
                // from OTHER rounds collected in this same call are
                // already recorded in the metrics/monitor, and dropping
                // them would leave submitters hanging on work that
                // completed. Log, drop this launch's entries (their
                // submitters are rejected at shutdown, as before), and
                // keep serving.
                log::error!(
                    "launch {} of round {} failed{}: {e:#} ({} requests dropped)",
                    c.index,
                    c.round,
                    if c.attempt > 0 { " after retry" } else { "" },
                    c.launch.entries.len()
                );
                return Ok(());
            }
        };
        let fused = c.launch.entries.len();
        if fused > 1 {
            self.metrics.record_superkernel_launch();
            shard.superkernel_launches += 1;
        } else {
            self.metrics.record_kernel_launch();
        }
        // Steal accounting: exported per-thief through the mirror, and
        // windowed per dwell for the controller's imbalance signal
        // (sustained stealing means the balancer's placement and reality
        // disagree — a candidate reason to re-decide the lane count).
        if c.stolen {
            shard.mirror.record_steal(c.executed_lane);
        }
        if shard.controller.is_some() {
            shard.win_launches += 1;
            if c.stolen {
                shard.win_steals += 1;
            }
        }
        // Calibrate this shard's launch-latency predictor with the
        // measured end-to-end launch duration (marshal + execute — what a
        // deadline actually waits on), tagged with how many lanes ITS
        // round kept resident — pipelined rounds in flight never
        // cross-attribute — then refresh the lock-free snapshot mirror.
        if let Some(cm) = &shard.cost_model {
            let mut cm = lock_recover(cm);
            cm.observe_concurrent(
                c.launch.class,
                c.launch.r_bucket,
                c.lanes_resident,
                res.service_s + res.marshal_s,
            );
            shard.mirror.record_calibration(cm.calibration_error());
            let lane_err = cm.lane_calibration_error(c.lanes_resident);
            shard.mirror.record_lane_calibration(c.lanes_resident, lane_err);
            if shard.controller.is_some() {
                // Feed the controller's mean-launch-duration signal the
                // SOLO-equivalent cost: deflate overlapped measurements by
                // their own round's calibrated stretch so the utility
                // model prices every candidate from one clean base.
                let deflated =
                    (res.service_s + res.marshal_s) / cm.lane_stretch(c.lanes_resident);
                shard.tracker.observe_launch(deflated);
            }
        }
        // Busy time lands on the lane that actually RAN the item (stolen
        // items bill the thief) — lane_busy_s is a utilization view, while
        // the cost-model feedback above keyed on the planned round tag.
        shard.mirror.record_launch(c.executed_lane, res.service_s + res.marshal_s);
        let mut outputs = res.outputs.into_iter();
        for entry in &c.launch.entries {
            let output = outputs.next().expect("one output per launch entry");
            let latency_s = c.done.duration_since(entry.arrived).as_secs_f64();
            // One deadline verdict per response, fed to BOTH the metrics
            // registry (status JSON / serve table) and the SLO monitor
            // (eviction-adjacent reporting) from this single point so the
            // two attainment views can't diverge.
            let met = c.done <= entry.deadline;
            if shard.controller.is_some() {
                // Windowed attainment for the controller's SLO valve.
                if met {
                    shard.win_hits += 1;
                } else {
                    shard.win_misses += 1;
                }
            }
            let handle = &self.tenant_metrics[entry.tenant];
            handle.record_completion(
                (latency_s * 1e9) as u64,
                (res.service_s * 1e9) as u64,
                entry.class.flops(),
            );
            handle.record_deadline(met);
            shard.flops += entry.class.flops();
            self.monitor.observe(entry.tenant, res.service_s);
            self.monitor.observe_deadline(entry.tenant, met);
            outcome.responses.push(InferenceResponse {
                id: entry.id,
                tenant: entry.tenant,
                trace_id: entry.trace_id,
                output,
                latency_s,
                service_s: res.service_s,
                fused_r: fused,
            });
        }
        Ok(())
    }

    /// Run rounds until all queues drain AND every in-flight pipelined
    /// round is collected; returns every response.
    pub fn run_until_drained(&mut self) -> Result<Vec<InferenceResponse>> {
        let mut all = Vec::new();
        while self.pending() > 0 || self.in_flight_rounds() > 0 {
            let out = self.run_round()?;
            all.extend(out.responses);
        }
        Ok(all)
    }

    /// Force an immediate monitor window check (tests/benches).
    pub fn force_check(&mut self) -> Vec<Eviction> {
        let evictions = self.monitor.check(&mut self.tenants);
        for ev in &evictions {
            let device = self.placer.device_of(ev.tenant);
            lock_recover(&self.shards[device].fusion_cache)
                .invalidate_tenant(ev.tenant);
            self.placer.release(ev.tenant);
        }
        evictions
    }

    /// Re-admit a previously evicted tenant: health returns to `Healthy`,
    /// the monitor's straggler state resets (a fresh EWMA — not the
    /// history that got it evicted), and the placement layer re-joins the
    /// tenant to its shape class's device (least-loaded fallback when the
    /// whole class left). Returns the device it landed on. A tenant that
    /// was never evicted keeps its current placement.
    pub fn readmit_tenant(&mut self, tenant: usize) -> Result<usize, Reject> {
        let t = self
            .tenants
            .get_mut(tenant)
            .ok_or_else(|| Reject::BadRequest(format!("unknown tenant {tenant}")))?;
        if t.health != crate::coordinator::tenant::Health::Evicted {
            return Ok(self.placer.device_of(tenant));
        }
        t.health = crate::coordinator::tenant::Health::Healthy;
        self.monitor.reset(tenant);
        let device = self.placer.readmit(tenant);
        self.monitor.set_device(tenant, device);
        Ok(device)
    }

    /// Feed an out-of-band latency observation to the SLO monitor —
    /// the anomaly-injection hook used by failure tests and the
    /// straggler_eviction example (the serve path observes automatically).
    pub fn monitor_observe(&mut self, tenant: usize, service_s: f64) {
        self.monitor.observe(tenant, service_s);
    }

    pub fn monitor(&self) -> &SloMonitor {
        &self.monitor
    }

    /// Fusion-cache accounting (weight-operand reuse across launches),
    /// summed across the per-shard caches.
    pub fn fusion_cache_stats(&self) -> FusionCacheStats {
        let mut total = FusionCacheStats::default();
        for shard in &self.shards {
            let st = lock_recover(&shard.fusion_cache).stats;
            total.hits += st.hits;
            total.misses += st.misses;
            total.entries += st.entries;
            total.evictions += st.evictions;
        }
        total
    }

    /// Replace every shard's fusion cache (benches/ablations: e.g.
    /// capacity 1 to force the cold path). Serving uses the default
    /// capacity-256 caches.
    pub fn set_fusion_cache_capacity(&mut self, capacity: usize) {
        for shard in &mut self.shards {
            *lock_recover(&shard.fusion_cache) = FusionCache::new(capacity);
        }
    }

    /// Metrics snapshot over the coordinator's lifetime, including the
    /// per-device section.
    pub fn snapshot(&self) -> crate::metrics::Snapshot {
        let mut snap = self.metrics.snapshot(self.started.elapsed().as_secs_f64());
        snap.devices = self.device_snapshots();
        snap
    }
}

#[cfg(test)]
mod tests {
    // Coordinator tests require artifacts; see
    // rust/tests/integration_coordinator.rs and
    // rust/tests/integration_pipeline.rs. Pure plumbing tests here.
    use super::*;
    use crate::config::ServerConfig;

    #[test]
    fn bad_artifact_dir_fails_fast() {
        let cfg = ServerConfig {
            artifacts_dir: "/nonexistent".into(),
            ..Default::default()
        };
        assert!(Coordinator::new(&cfg).is_err());
    }

    #[test]
    fn round_arena_counts_growth_only_after_warmup() {
        let mut arena = RoundArena::default();
        use crate::coordinator::batcher::Launch;
        use crate::coordinator::request::{InferenceRequest, Priority, ShapeClass};
        const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 8, n: 8, k: 8 };
        let mk = |n: usize, plan: &mut RoundPlan| {
            for i in 0..n {
                let now = Instant::now();
                plan.launches.push(Launch {
                    class: CLASS,
                    entries: vec![InferenceRequest {
                        id: i as u64,
                        tenant: 0,
                        class: CLASS,
                        payload: vec![],
                        arrived: now,
                        deadline: now,
                        priority: Priority::Normal,
                        trace_id: 0,
                    }],
                    r_bucket: 1,
                });
                plan.lane_of.push(i % 2);
            }
        };
        // Warmup round: grows the buffers, not the counter.
        let plan = arena.begin();
        mk(8, plan);
        plan.launches.drain(..);
        arena.finish();
        assert_eq!(arena.grows(), 0, "warmup growth is free");
        // Steady state at the warm size: no growth counted.
        for _ in 0..10 {
            let plan = arena.begin();
            mk(8, plan);
            plan.launches.drain(..);
            arena.finish();
        }
        assert_eq!(arena.grows(), 0, "steady rounds must reuse the arena");
        // A bigger round grows the buffers — and is counted.
        let plan = arena.begin();
        mk(64, plan);
        plan.launches.drain(..);
        arena.finish();
        assert!(arena.grows() >= 1, "post-warmup growth must be counted");
    }

    #[test]
    fn snapshot_mirror_reads_do_not_touch_the_cost_model_lock() {
        // Regression for the snapshot-path contention bug: the old
        // `device_snapshots` locked each shard's cost model and walked its
        // lane tracks per status call. The mirror is updated at completion
        // processing and read lock-free — here the cost-model mutex is
        // HELD while the mirror is read, which would deadlock if the
        // snapshot path still took the lock.
        use crate::coordinator::request::ShapeClass;
        const CLASS: ShapeClass =
            ShapeClass { kind: "batched_gemm", m: 64, n: 64, k: 64 };
        let mirror = SnapshotMirror::new(2);
        let cm: SharedCostModel = Arc::new(Mutex::new(CostModel::new()));
        {
            let mut guard = cm.lock().unwrap();
            guard.observe(CLASS, 4, 1e-3);
            guard.observe_concurrent(CLASS, 4, 2, 1.5e-3);
            mirror.record_calibration(guard.calibration_error());
            mirror.record_lane_calibration(2, guard.lane_calibration_error(2));
            mirror.record_launch(1, 2.5e-3);
            // Lock still held: mirror reads must not block on it.
            assert!(mirror.calibration_error() >= 0.0);
            assert_eq!(mirror.lane_launches(), vec![0, 1]);
            assert!((mirror.lane_busy_s()[1] - 2.5e-3).abs() < 1e-9);
            let calib = mirror.lane_calibration();
            assert_eq!(calib.len(), 1);
            assert_eq!(calib[0].0, 2);
        }
    }

    #[test]
    fn snapshot_mirror_clamps_and_hides_unobserved_counts() {
        let mirror = SnapshotMirror::new(1);
        assert!(mirror.lane_calibration().is_empty(), "nothing observed yet");
        // Lane counts beyond the configured width clamp / drop safely.
        mirror.record_launch(7, 1.0);
        assert_eq!(mirror.lane_launches(), vec![1]);
        mirror.record_lane_calibration(9, 0.5);
        assert!(mirror.lane_calibration().is_empty());
        // Solo calibration never enters the per-lane table.
        mirror.record_lane_calibration(1, 0.25);
        assert!(mirror.lane_calibration().is_empty());
    }
}
