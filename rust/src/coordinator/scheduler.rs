//! Scheduling policies: how queued problems map onto kernel launches.
//!
//! The paper's §3 baselines and §4 contribution, expressed over the real
//! PJRT execution path. Each policy drains the admission queues for one
//! scheduling round and emits a launch plan:
//!
//! * **Exclusive** — classic single-tenant batching: one tenant per round
//!   (rotating), its requests fused into its own super-kernel. High
//!   per-tenant throughput, no sharing.
//! * **TimeMux** — CUDA-context interleaving: strict round-robin across
//!   tenants, ONE problem per launch, one launch at a time. R launches for
//!   R problems; utilization per quantum is single-problem utilization.
//! * **SpaceMux** — Hyper-Q/streams: still one problem per launch, but the
//!   round drains every backlogged tenant, modeling concurrent streams
//!   (each launch is an independent small kernel, as MPS would run).
//! * **SpaceTime** — the contribution: cross-tenant same-class problems are
//!   merged by the [`DynamicBatcher`] into padded super-kernel launches.
//!
//! On CPU-PJRT the measured difference between TimeMux/SpaceMux and
//! SpaceTime is launch-count amortization — exactly the mechanism the paper
//! exploits; V100-scaled shapes come from `gpusim` (DESIGN.md §1).
//!
//! ## Deadline-aware planning (EDF)
//!
//! With [`SpaceTimeSched::deadline_aware`], SpaceTime stops being a pure
//! throughput maximizer and plans launches against request deadlines:
//!
//! 1. The round drains requests in global earliest-deadline-first order
//!    (the per-tenant queues are already EDF heaps).
//! 2. Planned launches are ordered by their most urgent member's deadline.
//! 3. Each launch's duration is predicted by the per-shard
//!    [`CostModel`]; a launch whose predicted completion (cumulative round
//!    time + own duration) would overrun its most urgent member's deadline
//!    is **split**: the *largest* re-bucketed prefix of deadline-sorted
//!    entries that is still predicted to make the deadline launches first
//!    (maximal prefix = minimal fusion loss; with power-of-two buckets
//!    splits land on bucket boundaries and cost only one extra launch
//!    overhead), and the remainder re-enters the plan against its own
//!    (later) deadline. A launch that cannot make its deadline even at
//!    r = 1 stays fused — splitting would only add overhead — and is
//!    **demoted to the end of the round**, so a known-lost launch never
//!    inflates the completion time of feasible launches behind it.
//!
//! Splitting trades a little fusion (extra launches, re-bucketed padding)
//! for the most urgent request's deadline — the space-time trade the paper
//! makes round-by-round, now steered by an explicit latency predictor
//! (arXiv:2512.18725) instead of FIFO luck. `Exclusive`/`TimeMux`/
//! `SpaceMux` stay strictly FIFO so the §3 baselines remain faithful.
//!
//! ## Spatial execution lanes
//!
//! With [`SpaceTimeSched::spatial_lanes`], "space" stops being a residual
//! of fusion and becomes a planned resource: each round's launches are
//! assigned to `lanes` concurrent streams that the driver executes
//! overlapped. Assignment is greedy **makespan balancing** — walk the
//! launches in their planned (urgency) order and append each to the lane
//! with the least predicted load (priced by the cost model when attached,
//! else by the FLOP-proportional [`launch_weight`] proxy). List scheduling
//! keeps the worst lane within `total/L + max single duration` of optimal
//! while preserving urgency order within every lane. Profit comes from
//! the concave occupancy curve: a super-kernel too small to fill the
//! device leaves SMs idle that another lane can use, at the price of a
//! co-location **interference stretch** the cost model calibrates from
//! measured overlapped launches (`CostModel::lane_stretch`; D-STACK's
//! GPU-share knees, arXiv:2304.13541, and DARIS's scheduler-owned
//! interference model, arXiv:2504.08795). The §3 baselines always plan a
//! single lane, and a one-launch round never overlaps with itself —
//! `lanes = 1` is exactly the pre-lane scheduler. Exported per device:
//! per-lane launch counts, busy time, and per-lane-count calibration
//! error (fig10: `benches/fig10_spatial_lanes.rs`; config knob `lanes`).
//!
//! ## Pipelined rounds and round tagging
//!
//! The driver overlaps planning with execution (`pipeline_depth` rounds
//! in flight on a persistent lane-worker pool), so a plan's verdicts must
//! survive being *executed later than they were made*: every launch the
//! driver dispatches is tagged with its round id and the lane count this
//! plan decided to keep resident (`RoundPlan::lanes_used`). Completions
//! echo the tag, and the cost model is fed at **that round's** lane
//! count — a plan's interference pricing and its measured feedback always
//! agree, no matter how many newer rounds were planned in between.
//! Schedulers that support the allocation-free hot path implement
//! [`Scheduler::plan_round_into`], filling the driver's recycled
//! per-shard `RoundPlan` (launch + lane vectors reused across rounds)
//! instead of allocating a fresh plan.
//!
//! ## The placement layer above
//!
//! Schedulers are deliberately **device-blind**: each instance plans
//! rounds over the one [`QueueSet`] it is handed. The multi-device
//! coordinator ([`crate::coordinator::driver`]) instantiates one scheduler
//! per device shard and routes requests to shards via
//! [`crate::coordinator::placement`] — least-loaded assignment with
//! shape-class affinity, so every request a scheduler could profitably
//! fuse is already in its queues. That layering keeps the §3/§4 policies
//! exactly as the paper describes them while the pool scales out: a
//! per-shard `plan_round` on an N-device pool is the same computation as
//! the paper's single-GPU round, N times in parallel. Per-device stats
//! (launches, drained, shed) are accounted in the driver, not here.

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::SchedulerKind;
use crate::coordinator::batcher::{DynamicBatcher, Launch, PaddingPolicy};
use crate::coordinator::costmodel::SharedCostModel;
use crate::coordinator::queue::QueueSet;
use crate::coordinator::request::InferenceRequest;
use crate::util::sync::lock_recover;

/// One scheduling round's launch plan.
#[derive(Debug, Default)]
pub struct RoundPlan {
    pub launches: Vec<Launch>,
    /// Spatial execution lane of each launch, parallel to `launches`
    /// (empty == everything on lane 0). Lanes execute *concurrently* in
    /// the driver; launches sharing a lane run in plan order. The §3
    /// baselines always stay single-lane.
    pub lane_of: Vec<usize>,
    /// Predicted cost of each launch, parallel to `launches` (empty for
    /// single-lane plans and the §3 baselines — the driver reads missing
    /// hints as 0.0). Rides each `WorkItem` as its `cost_hint` so the
    /// lane pool's steal-victim selection ranks backlogs by the same
    /// predicted durations the balancer packed with.
    pub cost_of: Vec<f64>,
    /// Concurrent lanes this plan spans (0 or 1 == serial round).
    pub n_lanes: usize,
    /// Requests drained this round (== sum of launch entries).
    pub drained: usize,
    /// Fused launches the deadline-aware planner split to protect an
    /// urgent member's deadline (0 for every non-EDF policy).
    pub deadline_splits: usize,
}

impl RoundPlan {
    /// Lane of launch `i` (lane 0 for single-lane plans).
    pub fn lane(&self, i: usize) -> usize {
        self.lane_of.get(i).copied().unwrap_or(0)
    }

    /// Distinct lanes that actually carry a launch this round.
    pub fn lanes_used(&self) -> usize {
        if self.launches.is_empty() {
            return 0;
        }
        if self.lane_of.is_empty() || self.n_lanes <= 1 {
            return 1;
        }
        let mut seen = vec![false; self.n_lanes];
        for i in 0..self.launches.len() {
            let l = self.lane(i).min(self.n_lanes - 1);
            seen[l] = true;
        }
        seen.iter().filter(|&&s| s).count()
    }
}

/// Relative duration proxy for lane balancing when no cost model is
/// attached: the launch's total lane work. Proportional weights are all the
/// greedy balancer needs.
pub fn launch_weight(launch: &Launch) -> f64 {
    launch.class.flops() * launch.r_bucket.max(1) as f64
}

/// Fraction of its predicted weight the cheapest-to-steal class is
/// accounted at when the balancer is steal-aware (see
/// [`SpaceTimeSched::assign_lanes_into`]). Halving keeps the distortion
/// bounded: the overpacked lane's predicted excess never exceeds what one
/// idle thief clears in a single steal of the class's own launches.
pub const STEAL_OVERPACK_DISCOUNT: f64 = 0.5;

/// A scheduling policy over the admission queues.
pub trait Scheduler: Send {
    /// Drain work for one round and plan launches.
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan;

    /// Like [`Scheduler::plan_round`], but planning against an explicit
    /// `now` (deadline budgets are `deadline - now`). The driver passes
    /// wall-clock time; simulations and benches pass a simulated clock.
    /// Policies without deadline logic ignore `now`.
    fn plan_round_at(&mut self, queues: &mut QueueSet, now: Instant) -> RoundPlan {
        let _ = now;
        self.plan_round(queues)
    }

    /// Plan a round **into** a recycled [`RoundPlan`] (the driver's
    /// per-shard arena): implementations that support the allocation-free
    /// hot path fill `out`'s vectors in place, reusing their capacity
    /// across rounds. The default overwrites `out` with a fresh plan —
    /// correct for the §3 baselines, which are not the perf path.
    fn plan_round_into(&mut self, queues: &mut QueueSet, now: Instant, out: &mut RoundPlan) {
        *out = self.plan_round_at(queues, now);
    }

    fn label(&self) -> &'static str;

    /// Batcher statistics if the policy batches (SpaceTime/Exclusive).
    fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        None
    }

    /// Re-target the spatial lane count mid-stream (the adaptive
    /// controller's reconfiguration hook): subsequent rounds plan across
    /// `lanes` concurrent lanes. The §3 baselines are single-lane by
    /// definition and ignore this (default no-op).
    fn set_lanes(&mut self, lanes: usize) {
        let _ = lanes;
    }

    /// Tell the policy the execution layer steals across lanes: the lane
    /// balancer may then deliberately overpack the cheapest-to-steal
    /// class, trusting idle thieves to rebalance at run time (see
    /// [`SpaceTimeSched`]). With `on = false` — and for every policy that
    /// keeps the default no-op — planning is bit-identical to the
    /// non-stealing build. The §3 baselines never steal.
    fn set_steal_aware(&mut self, on: bool) {
        let _ = on;
    }
}

/// Build the configured scheduler (paper-faithful `PadToBucket` batching,
/// fair drain).
pub fn make_scheduler(
    kind: SchedulerKind,
    buckets: Vec<usize>,
    max_batch: usize,
) -> Box<dyn Scheduler> {
    make_scheduler_with_policy(kind, buckets, max_batch, PaddingPolicy::PadToBucket, false)
}

/// Build the configured scheduler with explicit padding policy and
/// SLO-aware drain (space-time only — the other policies define their own
/// drain order).
pub fn make_scheduler_with_policy(
    kind: SchedulerKind,
    buckets: Vec<usize>,
    max_batch: usize,
    policy: PaddingPolicy,
    slo_aware: bool,
) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Exclusive => {
            Box::new(ExclusiveSched::with_policy(buckets, max_batch, policy))
        }
        SchedulerKind::TimeMux => Box::new(TimeMuxSched::new(buckets)),
        SchedulerKind::SpaceMux => Box::new(SpaceMuxSched::new(buckets)),
        SchedulerKind::SpaceTime => Box::new(
            SpaceTimeSched::with_policy(buckets, max_batch, policy).slo_aware(slo_aware),
        ),
    }
}

/// Build the configured scheduler with deadline-aware (EDF) planning.
/// Only `SpaceTime` consults the cost model; the §3 baselines stay FIFO so
/// they remain faithful to the paper — for them this falls back to
/// [`make_scheduler_with_policy`] with the plain drain order.
pub fn make_scheduler_deadline_aware(
    kind: SchedulerKind,
    buckets: Vec<usize>,
    max_batch: usize,
    policy: PaddingPolicy,
    cost: SharedCostModel,
    slack_s: f64,
) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::SpaceTime => Box::new(
            SpaceTimeSched::with_policy(buckets, max_batch, policy)
                .deadline_aware(cost, slack_s),
        ),
        other => make_scheduler_with_policy(other, buckets, max_batch, policy, false),
    }
}

/// Build the configured scheduler with the full knob set: padding policy,
/// SLO-aware drain, spatial `lanes`, and — when `edf_slack` is set along
/// with a cost model — deadline-aware planning. The §3 baselines ignore
/// every space-time knob (single lane, FIFO); SpaceTime prices its lane
/// balancing with `cost` when given, falling back to the FLOP-proportional
/// [`launch_weight`] proxy.
#[allow(clippy::too_many_arguments)]
pub fn make_scheduler_spatial(
    kind: SchedulerKind,
    buckets: Vec<usize>,
    max_batch: usize,
    policy: PaddingPolicy,
    slo_aware: bool,
    lanes: usize,
    cost: Option<SharedCostModel>,
    edf_slack: Option<f64>,
) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::SpaceTime => {
            let mut s = SpaceTimeSched::with_policy(buckets, max_batch, policy)
                .slo_aware(slo_aware);
            if let (Some(cm), Some(slack)) = (&cost, edf_slack) {
                s = s.deadline_aware(cm.clone(), slack);
            }
            Box::new(s.spatial_lanes(lanes, cost))
        }
        other => make_scheduler_with_policy(other, buckets, max_batch, policy, false),
    }
}

/// Drain up to `cap` requests from one tenant's queue.
fn drain_tenant(queues: &mut QueueSet, tenant: usize, cap: usize) -> Vec<InferenceRequest> {
    let mut out = Vec::new();
    while out.len() < cap {
        match queues.pop_tenant(tenant) {
            Some(r) => out.push(r),
            None => break,
        }
    }
    out
}

/// Single-problem launches (used by the time/space baselines): each request
/// becomes its own r=1 launch (smallest bucket).
fn singleton_launches(reqs: Vec<InferenceRequest>, bucket1: usize) -> Vec<Launch> {
    reqs.into_iter()
        .map(|r| Launch { class: r.class, entries: vec![r], r_bucket: bucket1 })
        .collect()
}

// ---------------------------------------------------------------------------

/// Exclusive access: one tenant owns the device per round.
pub struct ExclusiveSched {
    batcher: DynamicBatcher,
    next_tenant: usize,
}

impl ExclusiveSched {
    pub fn new(buckets: Vec<usize>, max_batch: usize) -> Self {
        Self::with_policy(buckets, max_batch, PaddingPolicy::PadToBucket)
    }

    pub fn with_policy(buckets: Vec<usize>, max_batch: usize, policy: PaddingPolicy) -> Self {
        Self {
            batcher: DynamicBatcher::with_policy(buckets, max_batch, policy),
            next_tenant: 0,
        }
    }
}

impl Scheduler for ExclusiveSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        let n = queues.n_tenants();
        if n == 0 {
            return RoundPlan::default();
        }
        // Rotate to the next backlogged tenant.
        for i in 0..n {
            let t = (self.next_tenant + i) % n;
            if queues.tenant(t).map_or(false, |q| !q.is_empty()) {
                self.next_tenant = (t + 1) % n;
                let reqs = drain_tenant(queues, t, self.batcher.max_batch());
                let drained = reqs.len();
                return RoundPlan {
                    launches: self.batcher.plan(reqs),
                    drained,
                    ..Default::default()
                };
            }
        }
        RoundPlan::default()
    }

    fn label(&self) -> &'static str {
        "exclusive"
    }

    fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        Some(self.batcher.stats)
    }
}

// ---------------------------------------------------------------------------

/// Time multiplexing: round-robin, one problem per context quantum.
pub struct TimeMuxSched {
    bucket1: usize,
    next_tenant: usize,
}

impl TimeMuxSched {
    pub fn new(buckets: Vec<usize>) -> Self {
        let bucket1 = buckets.iter().copied().min().unwrap_or(1);
        Self { bucket1, next_tenant: 0 }
    }
}

impl Scheduler for TimeMuxSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        let n = queues.n_tenants();
        if n == 0 {
            return RoundPlan::default();
        }
        for i in 0..n {
            let t = (self.next_tenant + i) % n;
            if queues.tenant(t).map_or(false, |q| !q.is_empty()) {
                self.next_tenant = (t + 1) % n;
                let reqs = drain_tenant(queues, t, 1);
                let drained = reqs.len();
                return RoundPlan {
                    launches: singleton_launches(reqs, self.bucket1),
                    drained,
                    ..Default::default()
                };
            }
        }
        RoundPlan::default()
    }

    fn label(&self) -> &'static str {
        "time-mux"
    }
}

// ---------------------------------------------------------------------------

/// Spatial multiplexing: every backlogged tenant gets a stream slot per
/// round; each problem is still its own kernel launch.
pub struct SpaceMuxSched {
    bucket1: usize,
}

impl SpaceMuxSched {
    pub fn new(buckets: Vec<usize>) -> Self {
        let bucket1 = buckets.iter().copied().min().unwrap_or(1);
        Self { bucket1 }
    }
}

impl Scheduler for SpaceMuxSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        let mut reqs = Vec::new();
        for t in queues.backlogged() {
            reqs.extend(drain_tenant(queues, t, 1));
        }
        let drained = reqs.len();
        RoundPlan {
            launches: singleton_launches(reqs, self.bucket1),
            drained,
            ..Default::default()
        }
    }

    fn label(&self) -> &'static str {
        "space-mux"
    }
}

// ---------------------------------------------------------------------------

/// Space-time scheduling (the paper's contribution): drain across tenants
/// and fuse same-class problems into super-kernels.
///
/// Two drain orders:
/// * **fair** (default): rotate across backlogged tenants one request per
///   pass — equal shares of every launch.
/// * **SLO-aware** (`slo_aware(true)`): per pass, visit backlogged tenants
///   by their head-of-queue *deadline* (arrival + tenant SLO), earliest
///   first — the paper's §4.1 "determine when to execute workloads based
///   on per-model SLOs". Urgent tenants get the early lanes and, when the
///   cap splits a round, the earlier launch.
pub struct SpaceTimeSched {
    batcher: DynamicBatcher,
    slo_aware: bool,
    edf: Option<EdfPlanner>,
    /// Spatial execution lanes the driver runs concurrently (>= 1). The
    /// planner balances each round's launches across lanes greedily by
    /// predicted duration, preserving urgency order within a lane.
    lanes: usize,
    /// Duration source for lane balancing when not in EDF mode (EDF reuses
    /// its own cost model). None falls back to the [`launch_weight`] proxy.
    lane_cost: Option<SharedCostModel>,
    /// The execution layer steals across lanes (set via
    /// [`Scheduler::set_steal_aware`]): the balancer discounts the round's
    /// cheapest shape class in its load accounting, deliberately
    /// overpacking it — misprediction there is cheap for a thief to fix,
    /// while the expensive classes stay strictly balanced. False keeps
    /// assignment bit-identical to the non-stealing planner.
    steal_aware: bool,
    /// Round-scratch buffers recycled across `plan_round_into` calls so a
    /// steady-state round plans without heap growth: backlogged tenant
    /// ids, the drained request staging vector, the EDF pass's working
    /// queue / output / demoted buffers, and the lane-balancer loads.
    scratch_ids: Vec<usize>,
    scratch_reqs: Vec<InferenceRequest>,
    scratch_queue: VecDeque<Launch>,
    scratch_kept: Vec<Launch>,
    scratch_doomed: Vec<Launch>,
    scratch_load: Vec<f64>,
}

/// Deadline-aware planning state: the shared per-shard cost model plus the
/// safety margin subtracted from every deadline budget.
struct EdfPlanner {
    cost: SharedCostModel,
    slack_s: f64,
}

impl SpaceTimeSched {
    pub fn new(buckets: Vec<usize>, max_batch: usize) -> Self {
        Self::with_policy(buckets, max_batch, PaddingPolicy::PadToBucket)
    }

    pub fn with_policy(buckets: Vec<usize>, max_batch: usize, policy: PaddingPolicy) -> Self {
        Self {
            batcher: DynamicBatcher::with_policy(buckets, max_batch, policy),
            slo_aware: false,
            edf: None,
            lanes: 1,
            lane_cost: None,
            steal_aware: false,
            scratch_ids: Vec::new(),
            scratch_reqs: Vec::new(),
            scratch_queue: VecDeque::new(),
            scratch_kept: Vec::new(),
            scratch_doomed: Vec::new(),
            scratch_load: Vec::new(),
        }
    }

    pub fn slo_aware(mut self, on: bool) -> Self {
        self.slo_aware = on;
        self
    }

    /// Plan rounds over `lanes` concurrent spatial lanes. `cost` (when
    /// given) prices launches for the greedy makespan balancing; without
    /// it — and outside EDF mode — the FLOP-proportional [`launch_weight`]
    /// proxy is used, which balances identically for homogeneous rounds.
    pub fn spatial_lanes(mut self, lanes: usize, cost: Option<SharedCostModel>) -> Self {
        self.lanes = lanes.max(1);
        self.lane_cost = cost;
        self
    }

    /// Enable deadline-aware (EDF) planning: drain earliest-deadline-first,
    /// order launches by urgency, and split any fused launch whose
    /// predicted completion would overrun its most urgent member's
    /// deadline (see the module docs). Implies the EDF drain order.
    pub fn deadline_aware(mut self, cost: SharedCostModel, slack_s: f64) -> Self {
        self.edf = Some(EdfPlanner { cost, slack_s: slack_s.max(0.0) });
        self.slo_aware = true;
        self
    }

    /// Plan one round into a recycled [`RoundPlan`] — the allocation-free
    /// hot path: the drained-request staging vector, the backlogged-id
    /// scratch, the EDF pass's working buffers, and the plan's own launch
    /// and lane vectors are all reused across rounds (only the per-launch
    /// entry vectors are freshly owned, because launches carry their
    /// requests away).
    // lint: hot-path
    // lint: pure
    fn plan_into(&mut self, queues: &mut QueueSet, now: Instant, out: &mut RoundPlan) {
        out.launches.clear();
        out.lane_of.clear();
        out.cost_of.clear();
        out.n_lanes = 0;
        out.drained = 0;
        out.deadline_splits = 0;
        let cap = self.batcher.max_batch();
        let mut reqs = std::mem::take(&mut self.scratch_reqs);
        let mut ids = std::mem::take(&mut self.scratch_ids);
        reqs.clear();
        if self.slo_aware {
            // Request-level EDF: repeatedly pop the globally earliest
            // head-of-queue deadline (each tenant queue is an EDF heap, so
            // the head is that tenant's most urgent request).
            while reqs.len() < cap {
                queues.backlogged_into(&mut ids);
                let next = ids.iter().copied().min_by_key(|&t| {
                    queues.tenant(t).and_then(|q| q.peek()).map(|r| r.deadline)
                });
                let Some(t) = next else { break };
                if let Some(r) = queues.pop_tenant(t) {
                    reqs.push(r);
                }
            }
        } else {
            // Fair drain: rotate across backlogged tenants taking one
            // request each until the cap or empty queues.
            'outer: loop {
                queues.backlogged_into(&mut ids);
                if ids.is_empty() {
                    break;
                }
                let mut took = false;
                for &t in &ids {
                    if reqs.len() >= cap {
                        break 'outer;
                    }
                    if let Some(r) = queues.pop_tenant(t) {
                        reqs.push(r);
                        took = true;
                    }
                }
                if !took {
                    break;
                }
            }
        }
        out.drained = reqs.len();
        self.batcher.plan_into(&mut reqs, &mut out.launches);
        self.scratch_reqs = reqs;
        self.scratch_ids = ids;
        if self.edf.is_some() {
            self.edf_pass(now, out);
        }
        let mut cost_of = std::mem::take(&mut out.cost_of);
        out.n_lanes = self.assign_lanes_into(&out.launches, &mut out.lane_of, &mut cost_of);
        out.cost_of = cost_of;
    }

    /// Deadline-protection pass over a planned round (module docs, EDF
    /// step 3), rewriting `out.launches` in place via recycled scratch.
    // lint: hot-path
    // lint: pure
    fn edf_pass(&mut self, now: Instant, out: &mut RoundPlan) {
        let Some(edf) = &self.edf else { return };

        // Deadline-protection pass: order launches most-urgent-first, then
        // walk the plan with a predicted-time cursor, splitting any fused
        // launch that would blow its most urgent member's deadline (module
        // docs, step 3). With spatial lanes a multi-launch round executes
        // overlapped and every launch stretches by the co-location
        // interference term, so price the pass at the configured lane
        // count's stretch: the serial stretched cursor upper-bounds any
        // single lane's stretched makespan, keeping every feasibility
        // verdict conservative (never optimistic about a deadline).
        let cost = lock_recover(&edf.cost);
        let slack = edf.slack_s;
        let stretch = if self.lanes > 1 && out.launches.len() > 1 {
            cost.lane_stretch(self.lanes.min(out.launches.len()))
        } else {
            1.0
        };
        out.launches.sort_by_key(|l| l.entries.iter().map(|e| e.deadline).min());
        let mut queue = std::mem::take(&mut self.scratch_queue);
        let mut kept = std::mem::take(&mut self.scratch_kept);
        // Launches whose most urgent deadline is unmakeable at any split:
        // executed LAST so they never delay feasible launches (their own
        // predicted time is excluded from the feasibility cursor).
        let mut doomed = std::mem::take(&mut self.scratch_doomed);
        queue.clear();
        kept.clear();
        doomed.clear();
        queue.extend(out.launches.drain(..));
        let mut splits = 0usize;
        let mut cursor = 0.0f64;
        while let Some(launch) = queue.pop_front() {
            let dur = cost.predict(launch.class, launch.r_bucket) * stretch;
            let earliest = launch
                .entries
                .iter()
                .map(|e| e.deadline)
                .min()
                .expect("batcher never emits empty launches");
            let budget = earliest.saturating_duration_since(now).as_secs_f64() - slack;
            if cursor + dur <= budget {
                cursor += dur;
                kept.push(launch);
                continue;
            }
            if launch.entries.len() <= 1 {
                doomed.push(launch);
                continue;
            }
            // Find the largest urgent prefix whose re-bucketed launch is
            // still predicted to make the earliest deadline. Under
            // SplitExact only exact-bucket prefixes qualify, preserving
            // the policy's zero-padding invariant across the split.
            let Launch { class, mut entries, r_bucket } = launch;
            entries.sort_by_key(|r| (r.deadline, r.tenant, r.id));
            let exact_only =
                self.batcher.policy() == crate::coordinator::batcher::PaddingPolicy::SplitExact;
            let mut split_k = None;
            for k in (1..entries.len()).rev() {
                let Some(bucket) = self.batcher.bucket_for(k) else { continue };
                if exact_only && bucket != k {
                    continue;
                }
                if cursor + cost.predict(class, bucket) * stretch <= budget {
                    split_k = Some(k);
                    break;
                }
            }
            match split_k {
                Some(k) => {
                    let (head, tails) = self
                        .batcher
                        .split_launch(Launch { class, entries, r_bucket }, k);
                    splits += 1;
                    cursor += cost.predict(head.class, head.r_bucket) * stretch;
                    kept.push(head);
                    // Each tail piece re-enters the plan at its own (later)
                    // urgency; it may be split again against that deadline.
                    for tail in tails {
                        let tail_key = tail.entries.iter().map(|e| e.deadline).min();
                        let pos = queue
                            .iter()
                            .position(|l| {
                                l.entries.iter().map(|e| e.deadline).min() > tail_key
                            })
                            .unwrap_or(queue.len());
                        queue.insert(pos, tail);
                    }
                }
                None => {
                    // Even the smallest feasible prefix misses: keep the
                    // fused launch whole (a split would add overhead
                    // without saving the deadline) and run it after the
                    // feasible launches.
                    entries.sort_by_key(|r| (r.tenant, r.id));
                    doomed.push(Launch { class, entries, r_bucket });
                }
            }
        }
        out.launches.extend(kept.drain(..));
        out.launches.extend(doomed.drain(..));
        out.deadline_splits = splits;
        // The EDF cost-model guard must drop before `assign_lanes_into`
        // re-locks the same mutex for balancing weights.
        drop(cost);
        self.scratch_queue = queue;
        self.scratch_kept = kept;
        self.scratch_doomed = doomed;
    }

    /// Greedy lane assignment: walk launches in plan (urgency) order and
    /// put each on the least-loaded lane by predicted duration — classic
    /// list scheduling, whose worst lane stays within
    /// `total/L + max single duration` of the optimum, while appending in
    /// order keeps each lane's launches urgency-sorted. Fills the
    /// recycled `lane_of` and `cost_of` vectors and returns the plan's
    /// lane count.
    ///
    /// Steal-aware overpacking: with [`SpaceTimeSched::steal_aware`] set,
    /// the round's cheapest shape class (by predicted per-launch cost) is
    /// accounted at [`STEAL_OVERPACK_DISCOUNT`] of its predicted weight,
    /// so the balancer concentrates it — if the prediction was right the
    /// lane finishes barely late and a thief evens it out for the price
    /// of one cheap migration; if the prediction was wrong (the paper's
    /// heavy-tail case), the work was going to move anyway and the other
    /// lanes' expensive launches were never put at risk. `cost_of`
    /// records the UNdiscounted predictions — victim selection must rank
    /// true remaining work, not the packing fiction.
    // lint: hot-path
    // lint: pure
    fn assign_lanes_into(
        &mut self,
        launches: &[Launch],
        lane_of: &mut Vec<usize>,
        cost_of: &mut Vec<f64>,
    ) -> usize {
        lane_of.clear();
        cost_of.clear();
        let n_lanes = self.lanes.min(launches.len()).max(1);
        if n_lanes <= 1 {
            return launches.len().min(1);
        }
        let mut load = std::mem::take(&mut self.scratch_load);
        load.clear();
        load.resize(n_lanes, 0.0);
        {
            let cost = self
                .edf
                .as_ref()
                .map(|e| &e.cost)
                .or_else(|| self.lane_cost.as_ref())
                .map(|c| lock_recover(c));
            let weight = |l: &Launch| match &cost {
                Some(cm) => cm.predict(l.class, l.r_bucket),
                None => launch_weight(l),
            };
            for l in launches {
                cost_of.push(weight(l));
            }
        }
        let discount_class = if self.steal_aware {
            launches
                .iter()
                .zip(cost_of.iter())
                .min_by(|a, b| a.1.partial_cmp(b.1).expect("costs are finite"))
                .map(|(l, _)| l.class)
        } else {
            None
        };
        for (i, l) in launches.iter().enumerate() {
            let lane = (0..n_lanes)
                .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
                .unwrap();
            lane_of.push(lane);
            let w = cost_of[i];
            load[lane] += if discount_class == Some(l.class) {
                w * STEAL_OVERPACK_DISCOUNT
            } else {
                w
            };
        }
        self.scratch_load = load;
        n_lanes
    }
}

impl Scheduler for SpaceTimeSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        self.plan_round_at(queues, Instant::now())
    }

    fn plan_round_at(&mut self, queues: &mut QueueSet, now: Instant) -> RoundPlan {
        let mut plan = RoundPlan::default();
        self.plan_into(queues, now, &mut plan);
        plan
    }

    fn plan_round_into(&mut self, queues: &mut QueueSet, now: Instant, out: &mut RoundPlan) {
        self.plan_into(queues, now, out);
    }

    fn label(&self) -> &'static str {
        "space-time"
    }

    fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        Some(self.batcher.stats)
    }

    /// Adaptive reconfiguration: later rounds balance across `lanes`
    /// lanes (>= 1) and the EDF pass re-prices deadlines at the new
    /// count's interference stretch. Scratch buffers are kept, so a
    /// resize does not reintroduce hot-path allocation.
    fn set_lanes(&mut self, lanes: usize) {
        self.lanes = lanes.max(1);
    }

    fn set_steal_aware(&mut self, on: bool) {
        self.steal_aware = on;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Priority, ShapeClass};
    use std::time::Instant;

    fn fill(queues: &mut QueueSet, tenant: usize, n: usize, class: ShapeClass) {
        for i in 0..n {
            queues
                .push(InferenceRequest {
                    id: (tenant * 1000 + i) as u64,
                    tenant,
                    class,
                    payload: vec![],
                    arrived: Instant::now(),
                    deadline: Instant::now(),
                    priority: Priority::Normal,
                    trace_id: 0,
                })
                .unwrap();
        }
    }

    fn buckets() -> Vec<usize> {
        vec![1, 2, 4, 8, 16, 32, 64]
    }

    const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 64, n: 64, k: 64 };

    #[test]
    fn spacetime_fuses_across_tenants_one_launch() {
        let mut q = QueueSet::new(4, 16);
        for t in 0..4 {
            fill(&mut q, t, 2, CLASS);
        }
        let mut s = SpaceTimeSched::new(buckets(), 64);
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.drained, 8);
        assert_eq!(plan.launches.len(), 1, "8 same-class problems -> 1 launch");
        assert_eq!(plan.launches[0].r_bucket, 8);
        assert!(q.is_empty());
    }

    #[test]
    fn spacetime_fair_drain_interleaves_tenants() {
        let mut q = QueueSet::new(2, 16);
        fill(&mut q, 0, 3, CLASS);
        fill(&mut q, 1, 3, CLASS);
        let mut s = SpaceTimeSched::new(buckets(), 4);
        let plan = s.plan_round(&mut q);
        // cap 4 -> fair drain takes 2 from each tenant; lanes are then
        // canonicalized (sorted by tenant) for fusion-cache stability.
        let tenants: Vec<usize> =
            plan.launches[0].entries.iter().map(|e| e.tenant).collect();
        assert_eq!(tenants, vec![0, 0, 1, 1]);
        assert_eq!(q.total_pending(), 2);
        // Fairness is about WHAT was drained, not lane order: each tenant
        // keeps exactly one leftover request.
        assert_eq!(q.tenant(0).unwrap().len(), 1);
        assert_eq!(q.tenant(1).unwrap().len(), 1);
    }

    #[test]
    fn timemux_one_problem_per_round_rotates() {
        let mut q = QueueSet::new(3, 16);
        for t in 0..3 {
            fill(&mut q, t, 1, CLASS);
        }
        let mut s = TimeMuxSched::new(buckets());
        let mut order = Vec::new();
        for _ in 0..3 {
            let plan = s.plan_round(&mut q);
            assert_eq!(plan.launches.len(), 1);
            assert_eq!(plan.launches[0].entries.len(), 1);
            assert_eq!(plan.launches[0].r_bucket, 1);
            order.push(plan.launches[0].entries[0].tenant);
        }
        assert_eq!(order, vec![0, 1, 2], "strict round-robin");
        assert!(s.plan_round(&mut q).launches.is_empty());
    }

    #[test]
    fn timemux_skips_idle_tenants() {
        let mut q = QueueSet::new(3, 16);
        fill(&mut q, 1, 2, CLASS);
        let mut s = TimeMuxSched::new(buckets());
        assert_eq!(s.plan_round(&mut q).launches[0].entries[0].tenant, 1);
        assert_eq!(s.plan_round(&mut q).launches[0].entries[0].tenant, 1);
    }

    #[test]
    fn spacemux_one_launch_per_backlogged_tenant() {
        let mut q = QueueSet::new(4, 16);
        fill(&mut q, 0, 2, CLASS);
        fill(&mut q, 2, 1, CLASS);
        let mut s = SpaceMuxSched::new(buckets());
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.launches.len(), 2, "tenants 0 and 2");
        assert!(plan.launches.iter().all(|l| l.entries.len() == 1));
        let plan2 = s.plan_round(&mut q);
        assert_eq!(plan2.launches.len(), 1, "only tenant 0 still backlogged");
    }

    #[test]
    fn exclusive_serves_single_tenant_batched() {
        let mut q = QueueSet::new(2, 16);
        fill(&mut q, 0, 3, CLASS);
        fill(&mut q, 1, 5, CLASS);
        let mut s = ExclusiveSched::new(buckets(), 64);
        let p0 = s.plan_round(&mut q);
        assert_eq!(p0.launches.len(), 1);
        assert!(p0.launches[0].entries.iter().all(|e| e.tenant == 0));
        assert_eq!(p0.drained, 3);
        let p1 = s.plan_round(&mut q);
        assert!(p1.launches[0].entries.iter().all(|e| e.tenant == 1));
        assert_eq!(p1.drained, 5);
    }

    #[test]
    fn slo_aware_drains_urgent_tenant_into_first_launch() {
        use std::time::Duration;
        let mut q = QueueSet::new(3, 16);
        let now = Instant::now();
        // Tenant 2 has the tightest deadline, tenant 0 the loosest.
        for (tenant, slo_ms) in [(0usize, 300u64), (1, 200), (2, 50)] {
            for i in 0..2 {
                q.push(InferenceRequest {
                    id: (tenant * 10 + i) as u64,
                    tenant,
                    class: CLASS,
                    payload: vec![],
                    arrived: now,
                    deadline: now + Duration::from_millis(slo_ms),
                    priority: Priority::Normal,
                    trace_id: 0,
                })
                .unwrap();
            }
        }
        // Cap 2: only one tenant's worth per pass fits the first launch.
        let mut s = SpaceTimeSched::new(buckets(), 2).slo_aware(true);
        let plan = s.plan_round(&mut q);
        let first = &plan.launches[0];
        assert!(
            first.entries.iter().all(|e| e.tenant == 2),
            "tightest-SLO tenant must fill the first launch, got {:?}",
            first.entries.iter().map(|e| e.tenant).collect::<Vec<_>>()
        );
        // Fair drain (default) would have taken one from each tenant.
        let mut q2 = QueueSet::new(3, 16);
        for (tenant, slo_ms) in [(0usize, 300u64), (1, 200), (2, 50)] {
            q2.push(InferenceRequest {
                id: tenant as u64,
                tenant,
                class: CLASS,
                payload: vec![],
                arrived: now,
                deadline: now + Duration::from_millis(slo_ms),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }
        let mut fair = SpaceTimeSched::new(buckets(), 2);
        let plan2 = fair.plan_round(&mut q2);
        let tenants: Vec<usize> =
            plan2.launches[0].entries.iter().map(|e| e.tenant).collect();
        assert_eq!(tenants, vec![0, 1], "fair drain visits ascending ids");
    }

    #[test]
    fn deadline_aware_splits_overfull_launch_to_protect_urgent_deadline() {
        use crate::coordinator::costmodel::CostModel;
        use std::sync::{Arc, Mutex};
        use std::time::Duration;

        let now = Instant::now();
        // Calibrate the model by hand: r=8 fused launches take 100 ms,
        // r=4 take 10 ms.
        let mut cm = CostModel::new();
        cm.observe(CLASS, 8, 0.100);
        cm.observe(CLASS, 4, 0.010);
        let cost = Arc::new(Mutex::new(cm));

        let mut q = QueueSet::new(8, 16);
        // 4 urgent requests (20 ms out) + 4 loose ones (10 s out).
        for t in 0..8usize {
            let slo = if t < 4 {
                Duration::from_millis(20)
            } else {
                Duration::from_secs(10)
            };
            q.push(InferenceRequest {
                id: t as u64,
                tenant: t,
                class: CLASS,
                payload: vec![],
                arrived: now,
                deadline: now + slo,
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }

        let mut s =
            SpaceTimeSched::new(buckets(), 8).deadline_aware(cost, 0.0);
        let plan = s.plan_round_at(&mut q, now);
        assert_eq!(plan.drained, 8);
        assert_eq!(
            plan.deadline_splits, 1,
            "the 8-wide fused launch (predicted 100 ms) must split to \
             protect the 20 ms deadlines"
        );
        assert_eq!(plan.launches.len(), 2);
        let first = &plan.launches[0];
        assert_eq!(first.r_bucket, 4);
        assert!(
            first.entries.iter().all(|e| e.tenant < 4),
            "urgent requests fill the protected launch, got {:?}",
            first.entries.iter().map(|e| e.tenant).collect::<Vec<_>>()
        );
        // Conservation: every drained request is in exactly one launch.
        let total: usize = plan.launches.iter().map(|l| l.entries.len()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn deadline_aware_keeps_hopeless_launch_fused() {
        use crate::coordinator::costmodel::CostModel;
        use std::sync::{Arc, Mutex};

        let now = Instant::now();
        let mut cm = CostModel::new();
        for r in [1usize, 2, 4, 8] {
            cm.observe(CLASS, r, 0.050); // every bucket takes 50 ms
        }
        let cost = Arc::new(Mutex::new(cm));
        let mut q = QueueSet::new(4, 16);
        for t in 0..4usize {
            q.push(InferenceRequest {
                id: t as u64,
                tenant: t,
                class: CLASS,
                payload: vec![],
                arrived: now,
                // Deadline already effectively now: no bucket can make it.
                deadline: now,
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }
        let mut s =
            SpaceTimeSched::new(buckets(), 8).deadline_aware(cost, 0.0);
        let plan = s.plan_round_at(&mut q, now);
        assert_eq!(plan.deadline_splits, 0, "splitting cannot save anyone");
        assert_eq!(plan.launches.len(), 1, "stays fused");
        assert_eq!(plan.launches[0].entries.len(), 4);
    }

    #[test]
    fn deadline_aware_demotes_lost_launch_behind_feasible_ones() {
        use crate::coordinator::costmodel::CostModel;
        use std::sync::{Arc, Mutex};
        use std::time::Duration;

        const CLASS_B: ShapeClass =
            ShapeClass { kind: "batched_gemm", m: 32, n: 32, k: 32 };
        let now = Instant::now();
        let mut cm = CostModel::new();
        cm.observe(CLASS, 1, 0.050);
        cm.observe(CLASS, 2, 0.050); // class A: 50 ms whatever the bucket
        cm.observe(CLASS_B, 2, 0.010); // class B: 10 ms
        let cost = Arc::new(Mutex::new(cm));
        let mut q = QueueSet::new(4, 16);
        // Class A requests are already past their deadline (lost); class B
        // has 30 ms of slack — feasible only if A doesn't run first.
        for t in 0..2usize {
            q.push(InferenceRequest {
                id: t as u64,
                tenant: t,
                class: CLASS,
                payload: vec![],
                arrived: now,
                deadline: now,
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }
        for t in 2..4usize {
            q.push(InferenceRequest {
                id: t as u64,
                tenant: t,
                class: CLASS_B,
                payload: vec![],
                arrived: now,
                deadline: now + Duration::from_millis(30),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }
        let mut s = SpaceTimeSched::new(buckets(), 8).deadline_aware(cost, 0.0);
        let plan = s.plan_round_at(&mut q, now);
        assert_eq!(plan.launches.len(), 2);
        assert_eq!(
            plan.launches[0].class, CLASS_B,
            "feasible launch runs first; the lost one is demoted"
        );
        assert_eq!(plan.launches[1].class, CLASS);
        assert_eq!(plan.deadline_splits, 0);
    }

    #[test]
    fn plain_spacetime_never_splits() {
        let mut q = QueueSet::new(4, 16);
        for t in 0..4 {
            fill(&mut q, t, 2, CLASS);
        }
        let mut s = SpaceTimeSched::new(buckets(), 64);
        let plan = s.plan_round_at(&mut q, Instant::now());
        assert_eq!(plan.deadline_splits, 0);
        assert_eq!(plan.launches.len(), 1);
    }

    const CLASS_SMALL: ShapeClass = ShapeClass { kind: "batched_gemm", m: 32, n: 32, k: 32 };
    const CLASS_BIG: ShapeClass =
        ShapeClass { kind: "batched_gemm", m: 128, n: 128, k: 128 };

    #[test]
    fn spatial_lanes_assign_every_launch_to_exactly_one_lane() {
        let mut q = QueueSet::new(6, 16);
        fill(&mut q, 0, 2, CLASS_SMALL);
        fill(&mut q, 1, 2, CLASS);
        fill(&mut q, 2, 2, CLASS_BIG);
        let mut s = SpaceTimeSched::new(buckets(), 64).spatial_lanes(2, None);
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.launches.len(), 3, "one launch per class");
        assert_eq!(plan.lane_of.len(), plan.launches.len());
        assert_eq!(plan.n_lanes, 2);
        assert!(plan.lane_of.iter().all(|&l| l < plan.n_lanes));
        assert_eq!(plan.lanes_used(), 2, "both lanes carry work");
    }

    #[test]
    fn lane_assignment_within_greedy_makespan_bound() {
        let mut q = QueueSet::new(8, 32);
        for t in 0..2 {
            fill(&mut q, t, 3, CLASS_SMALL);
        }
        for t in 2..4 {
            fill(&mut q, t, 3, CLASS);
        }
        for t in 4..6 {
            fill(&mut q, t, 3, CLASS_BIG);
        }
        let mut s = SpaceTimeSched::new(buckets(), 64).spatial_lanes(3, None);
        let plan = s.plan_round(&mut q);
        assert!(plan.launches.len() >= 3);
        let weights: Vec<f64> = plan.launches.iter().map(launch_weight).collect();
        let mut loads = vec![0.0f64; plan.n_lanes];
        for (i, &w) in weights.iter().enumerate() {
            loads[plan.lane(i)] += w;
        }
        let total: f64 = weights.iter().sum();
        let max_single = weights.iter().cloned().fold(0.0, f64::max);
        let worst = loads.iter().cloned().fold(0.0, f64::max);
        assert!(
            worst <= total / plan.n_lanes as f64 + max_single + 1e-9,
            "greedy bound violated: worst {worst}, total {total}, max {max_single}"
        );
    }

    #[test]
    fn single_launch_round_stays_single_lane() {
        let mut q = QueueSet::new(4, 16);
        for t in 0..4 {
            fill(&mut q, t, 2, CLASS);
        }
        let mut s = SpaceTimeSched::new(buckets(), 64).spatial_lanes(4, None);
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.launches.len(), 1);
        assert_eq!(plan.n_lanes, 1, "a lone launch cannot overlap itself");
        assert!(plan.lane_of.is_empty());
        assert_eq!(plan.lanes_used(), 1);
    }

    #[test]
    fn baselines_never_plan_multiple_lanes() {
        use crate::config::SchedulerKind::*;
        for kind in [Exclusive, TimeMux, SpaceMux] {
            let mut q = QueueSet::new(4, 16);
            fill(&mut q, 0, 2, CLASS_SMALL);
            fill(&mut q, 1, 2, CLASS_BIG);
            let mut s = make_scheduler(kind, buckets(), 8);
            while !q.is_empty() {
                let plan = s.plan_round(&mut q);
                assert!(plan.n_lanes <= 1, "{} multi-lane", s.label());
                assert!(plan.lane_of.is_empty());
                assert!(plan.lanes_used() <= 1);
            }
        }
    }

    #[test]
    fn edf_lane_assignment_keeps_urgency_order_within_lane() {
        use crate::coordinator::costmodel::CostModel;
        use std::sync::{Arc, Mutex};
        use std::time::Duration;

        let now = Instant::now();
        let mut cm = CostModel::new();
        for r in [1usize, 2, 4] {
            cm.observe(CLASS_SMALL, r, 0.010);
            cm.observe(CLASS_BIG, r, 0.010);
        }
        let cost = Arc::new(Mutex::new(cm));
        let mut q = QueueSet::new(8, 16);
        for t in 0..4usize {
            let class = if t % 2 == 0 { CLASS_SMALL } else { CLASS_BIG };
            q.push(InferenceRequest {
                id: t as u64,
                tenant: t,
                class,
                payload: vec![],
                arrived: now,
                deadline: now + Duration::from_millis(100 + 50 * t as u64),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }
        let mut s = SpaceTimeSched::new(buckets(), 8)
            .deadline_aware(cost, 0.0)
            .spatial_lanes(2, None);
        let plan = s.plan_round_at(&mut q, now);
        assert_eq!(plan.lane_of.len(), plan.launches.len());
        // Within each lane, launches keep the plan's urgency order.
        for lane in 0..plan.n_lanes {
            let deadlines: Vec<_> = plan
                .launches
                .iter()
                .enumerate()
                .filter(|&(i, _)| plan.lane(i) == lane)
                .map(|(_, l)| l.entries.iter().map(|e| e.deadline).min().unwrap())
                .collect();
            assert!(
                deadlines.windows(2).all(|w| w[0] <= w[1]),
                "lane {lane} out of urgency order"
            );
        }
    }

    #[test]
    fn edf_prices_deadlines_at_the_lane_interference_stretch() {
        use crate::coordinator::costmodel::CostModel;
        use std::sync::{Arc, Mutex};
        use std::time::Duration;

        // Solo, the urgent fused launch fits its deadline (30 ms <= 40 ms);
        // at a learned 2-lane stretch of 2.0 it does not (60 ms > 40 ms),
        // so the lane-aware planner must split where the solo planner
        // would not.
        let calibrated = || {
            let mut cm = CostModel::new();
            cm.observe(CLASS, 2, 0.030);
            cm.observe(CLASS, 1, 0.015);
            cm.observe(CLASS_B, 2, 0.001);
            cm.observe_concurrent(CLASS, 2, 2, 0.060); // stretch(2) == 2.0
            Arc::new(Mutex::new(cm))
        };
        const CLASS_B: ShapeClass =
            ShapeClass { kind: "batched_gemm", m: 48, n: 48, k: 48 };
        let fill_round = |q: &mut QueueSet, now: Instant| {
            for t in 0..2usize {
                q.push(InferenceRequest {
                    id: t as u64,
                    tenant: t,
                    class: CLASS,
                    payload: vec![],
                    arrived: now,
                    deadline: now + Duration::from_millis(40),
                    priority: Priority::Normal,
                    trace_id: 0,
                })
                .unwrap();
            }
            for t in 2..4usize {
                q.push(InferenceRequest {
                    id: t as u64,
                    tenant: t,
                    class: CLASS_B,
                    payload: vec![],
                    arrived: now,
                    deadline: now + Duration::from_secs(10),
                    priority: Priority::Normal,
                    trace_id: 0,
                })
                .unwrap();
            }
        };
        let now = Instant::now();
        let mut q = QueueSet::new(4, 16);
        fill_round(&mut q, now);
        let mut solo = SpaceTimeSched::new(buckets(), 4).deadline_aware(calibrated(), 0.0);
        let plan = solo.plan_round_at(&mut q, now);
        assert_eq!(plan.deadline_splits, 0, "solo: 30 ms fits the 40 ms budget");

        let mut q = QueueSet::new(4, 16);
        fill_round(&mut q, now);
        let mut laned = SpaceTimeSched::new(buckets(), 4)
            .deadline_aware(calibrated(), 0.0)
            .spatial_lanes(2, None);
        let plan = laned.plan_round_at(&mut q, now);
        assert_eq!(
            plan.deadline_splits, 1,
            "2-lane stretch 2.0 blows the 40 ms budget: must split"
        );
        assert_eq!(plan.launches[0].class, CLASS);
        assert_eq!(plan.launches[0].r_bucket, 1, "protected prefix at r=1");
    }

    #[test]
    fn make_scheduler_spatial_wires_lanes_and_edf() {
        use crate::coordinator::costmodel::CostModel;
        use std::sync::{Arc, Mutex};
        let cost = Arc::new(Mutex::new(CostModel::new()));
        let mut s = make_scheduler_spatial(
            SchedulerKind::SpaceTime,
            buckets(),
            64,
            PaddingPolicy::PadToBucket,
            false,
            2,
            Some(cost),
            Some(0.0),
        );
        assert_eq!(s.label(), "space-time");
        let mut q = QueueSet::new(4, 16);
        fill(&mut q, 0, 2, CLASS_SMALL);
        fill(&mut q, 1, 2, CLASS_BIG);
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.n_lanes, 2);
        // Baselines pass through untouched.
        let t = make_scheduler_spatial(
            SchedulerKind::TimeMux,
            buckets(),
            64,
            PaddingPolicy::PadToBucket,
            false,
            4,
            None,
            None,
        );
        assert_eq!(t.label(), "time-mux");
    }

    #[test]
    fn make_scheduler_labels() {
        use crate::config::SchedulerKind::*;
        for (k, l) in [
            (Exclusive, "exclusive"),
            (TimeMux, "time-mux"),
            (SpaceMux, "space-mux"),
            (SpaceTime, "space-time"),
        ] {
            assert_eq!(make_scheduler(k, buckets(), 8).label(), l);
        }
    }

    #[test]
    fn plan_round_into_reuses_the_recycled_plan() {
        // The driver's arena hands the same RoundPlan back every round:
        // stale state must be cleared, results must match a fresh plan,
        // and steady-state rounds must not regrow the vectors.
        let mut s = SpaceTimeSched::new(buckets(), 8).spatial_lanes(2, None);
        let mut recycled = RoundPlan::default();
        // Poison the recycled plan with stale junk.
        recycled.n_lanes = 9;
        recycled.drained = 99;
        recycled.deadline_splits = 7;
        for round in 0..12 {
            let mut q = QueueSet::new(4, 16);
            fill(&mut q, 0, 2, CLASS_SMALL);
            fill(&mut q, 1, 2, CLASS_BIG);
            let mut q2 = QueueSet::new(4, 16);
            fill(&mut q2, 0, 2, CLASS_SMALL);
            fill(&mut q2, 1, 2, CLASS_BIG);
            s.plan_round_into(&mut q, Instant::now(), &mut recycled);
            let mut fresh_sched = SpaceTimeSched::new(buckets(), 8).spatial_lanes(2, None);
            let fresh = fresh_sched.plan_round_at(&mut q2, Instant::now());
            assert_eq!(recycled.launches.len(), fresh.launches.len(), "round {round}");
            assert_eq!(recycled.lane_of, fresh.lane_of);
            assert_eq!(recycled.n_lanes, fresh.n_lanes);
            assert_eq!(recycled.drained, fresh.drained);
            assert_eq!(recycled.deadline_splits, 0);
            let ids =
                |p: &RoundPlan| -> Vec<u64> {
                    p.launches.iter().flat_map(|l| l.entries.iter().map(|e| e.id)).collect()
                };
            assert_eq!(ids(&recycled), ids(&fresh), "same drain order and lanes");
        }
        // Steady state: planning the same shape of round must not have
        // grown the recycled vectors past their warm capacity.
        let caps = (recycled.launches.capacity(), recycled.lane_of.capacity());
        for _ in 0..8 {
            let mut q = QueueSet::new(4, 16);
            fill(&mut q, 0, 2, CLASS_SMALL);
            fill(&mut q, 1, 2, CLASS_BIG);
            s.plan_round_into(&mut q, Instant::now(), &mut recycled);
        }
        assert_eq!(
            (recycled.launches.capacity(), recycled.lane_of.capacity()),
            caps,
            "steady-state planning must reuse the recycled plan's buffers"
        );
    }

    #[test]
    fn set_lanes_retargets_later_rounds() {
        let mut s = SpaceTimeSched::new(buckets(), 64).spatial_lanes(1, None);
        let fill2 = |q: &mut QueueSet| {
            fill(q, 0, 2, CLASS_SMALL);
            fill(q, 1, 2, CLASS_BIG);
        };
        let mut q = QueueSet::new(4, 16);
        fill2(&mut q);
        assert_eq!(s.plan_round(&mut q).n_lanes, 1);
        s.set_lanes(3);
        let mut q = QueueSet::new(4, 16);
        fill2(&mut q);
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.n_lanes, 2, "2 launches span min(3, 2) lanes");
        s.set_lanes(0);
        let mut q = QueueSet::new(4, 16);
        fill2(&mut q);
        assert_eq!(s.plan_round(&mut q).n_lanes, 1, "clamped to >= 1");
        // Baselines ignore the hook.
        let mut t = make_scheduler(SchedulerKind::TimeMux, buckets(), 8);
        t.set_lanes(4);
        let mut q = QueueSet::new(4, 16);
        fill(&mut q, 0, 2, CLASS);
        assert!(t.plan_round(&mut q).n_lanes <= 1);
    }

    #[test]
    fn steal_aware_overpacks_only_the_cheapest_class() {
        // flops = 2*m*n*k for batched_gemm: weight ratio BIG:SMALL = 3:2,
        // chosen so the greedy trace DIFFERS between the two modes.
        let big = ShapeClass { kind: "batched_gemm", m: 3, n: 2, k: 1 };
        let small = ShapeClass { kind: "batched_gemm", m: 2, n: 2, k: 1 };
        let launch = |class: ShapeClass| Launch { class, entries: vec![], r_bucket: 1 };
        let launches =
            vec![launch(big), launch(small), launch(small), launch(small)];
        let expected: Vec<f64> = launches.iter().map(launch_weight).collect();

        // Off (default): plain least-loaded list scheduling splits the
        // small class across both lanes.
        let mut off = SpaceTimeSched::new(buckets(), 8).spatial_lanes(2, None);
        let (mut lane_off, mut cost_off) = (Vec::new(), Vec::new());
        let n = off.assign_lanes_into(&launches, &mut lane_off, &mut cost_off);
        assert_eq!(n, 2);
        assert_eq!(lane_off, vec![0, 1, 1, 0]);
        assert_eq!(cost_off, expected, "hints are the undiscounted predictions");

        // On: the small (cheapest) class is accounted at half weight, so
        // the balancer concentrates ALL of it on one lane — overpacked on
        // purpose, trusting thieves to even it out at run time.
        let mut on = SpaceTimeSched::new(buckets(), 8).spatial_lanes(2, None);
        on.set_steal_aware(true);
        let (mut lane_on, mut cost_on) = (Vec::new(), Vec::new());
        on.assign_lanes_into(&launches, &mut lane_on, &mut cost_on);
        assert_eq!(lane_on, vec![0, 1, 1, 1], "cheapest class packed together");
        assert_eq!(cost_on, expected, "hints must NOT carry the discount");

        // Turning it back off restores the exact non-stealing assignment.
        on.set_steal_aware(false);
        let (mut lane_back, mut cost_back) = (Vec::new(), Vec::new());
        on.assign_lanes_into(&launches, &mut lane_back, &mut cost_back);
        assert_eq!(lane_back, lane_off, "steal-off must be bit-identical");
        assert_eq!(cost_back, cost_off);

        // Baselines ignore the hook entirely.
        let mut t = make_scheduler(SchedulerKind::TimeMux, buckets(), 8);
        t.set_steal_aware(true);
        let mut q = QueueSet::new(4, 16);
        fill(&mut q, 0, 2, CLASS);
        assert!(t.plan_round(&mut q).cost_of.is_empty());
    }

    #[test]
    fn empty_queues_empty_plan() {
        let mut q = QueueSet::new(2, 4);
        for kind in [
            make_scheduler(crate::config::SchedulerKind::SpaceTime, buckets(), 8),
            make_scheduler(crate::config::SchedulerKind::TimeMux, buckets(), 8),
        ]
        .iter_mut()
        {
            let plan = kind.plan_round(&mut q);
            assert_eq!(plan.drained, 0);
            assert!(plan.launches.is_empty());
        }
    }

    /// Regression for the poisoned-mutex recovery path: a panic while the
    /// shared cost model's guard is held poisons the mutex, and before
    /// `lock_recover` every later round's EDF pass (and the driver's
    /// admission/calibration paths) would panic on `lock().unwrap()` —
    /// one contained failure became a shard-wide crash. Planning must
    /// keep working against the recovered (still-consistent) model.
    #[test]
    fn planning_survives_a_poisoned_cost_model() {
        use crate::coordinator::costmodel::CostModel;
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::{Arc, Mutex};
        use std::time::Duration;

        let now = Instant::now();
        let mut cm = CostModel::new();
        cm.observe(CLASS, 8, 0.100);
        cm.observe(CLASS, 4, 0.010);
        let cost = Arc::new(Mutex::new(cm));

        // Poison it: panic with the guard held, as a panicking caller
        // anywhere in the serve loop would.
        let poisoner = catch_unwind(AssertUnwindSafe(|| {
            let _guard = cost.lock().unwrap();
            panic!("simulated panic while holding the cost-model lock");
        }));
        assert!(poisoner.is_err());
        assert!(cost.is_poisoned(), "the mutex must actually be poisoned");

        let mut q = QueueSet::new(8, 16);
        for t in 0..8usize {
            let slo = if t < 4 {
                Duration::from_millis(20)
            } else {
                Duration::from_secs(10)
            };
            q.push(InferenceRequest {
                id: t as u64,
                tenant: t,
                class: CLASS,
                payload: vec![],
                arrived: now,
                deadline: now + slo,
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }
        let mut s = SpaceTimeSched::new(buckets(), 8)
            .deadline_aware(cost, 0.0)
            .spatial_lanes(2, None);
        // Both cost-model lock sites run here: the EDF pass and the lane
        // balancer. The plan must come out exactly as with a healthy
        // mutex — the model's data is untouched by the panic.
        let plan = s.plan_round_at(&mut q, now);
        assert_eq!(plan.drained, 8);
        assert_eq!(plan.deadline_splits, 1, "EDF still splits for the urgent four");
        let total: usize = plan.launches.iter().map(|l| l.entries.len()).sum();
        assert_eq!(total, 8, "conservation across the recovered lock");
    }
}
