//! Scheduling policies: how queued problems map onto kernel launches.
//!
//! The paper's §3 baselines and §4 contribution, expressed over the real
//! PJRT execution path. Each policy drains the admission queues for one
//! scheduling round and emits a launch plan:
//!
//! * **Exclusive** — classic single-tenant batching: one tenant per round
//!   (rotating), its requests fused into its own super-kernel. High
//!   per-tenant throughput, no sharing.
//! * **TimeMux** — CUDA-context interleaving: strict round-robin across
//!   tenants, ONE problem per launch, one launch at a time. R launches for
//!   R problems; utilization per quantum is single-problem utilization.
//! * **SpaceMux** — Hyper-Q/streams: still one problem per launch, but the
//!   round drains every backlogged tenant, modeling concurrent streams
//!   (each launch is an independent small kernel, as MPS would run).
//! * **SpaceTime** — the contribution: cross-tenant same-class problems are
//!   merged by the [`DynamicBatcher`] into padded super-kernel launches.
//!
//! On CPU-PJRT the measured difference between TimeMux/SpaceMux and
//! SpaceTime is launch-count amortization — exactly the mechanism the paper
//! exploits; V100-scaled shapes come from `gpusim` (DESIGN.md §1).
//!
//! ## The placement layer above
//!
//! Schedulers are deliberately **device-blind**: each instance plans
//! rounds over the one [`QueueSet`] it is handed. The multi-device
//! coordinator ([`crate::coordinator::driver`]) instantiates one scheduler
//! per device shard and routes requests to shards via
//! [`crate::coordinator::placement`] — least-loaded assignment with
//! shape-class affinity, so every request a scheduler could profitably
//! fuse is already in its queues. That layering keeps the §3/§4 policies
//! exactly as the paper describes them while the pool scales out: a
//! per-shard `plan_round` on an N-device pool is the same computation as
//! the paper's single-GPU round, N times in parallel. Per-device stats
//! (launches, drained, shed) are accounted in the driver, not here.

use crate::config::SchedulerKind;
use crate::coordinator::batcher::{DynamicBatcher, Launch, PaddingPolicy};
use crate::coordinator::queue::QueueSet;
use crate::coordinator::request::InferenceRequest;

/// One scheduling round's launch plan.
#[derive(Debug, Default)]
pub struct RoundPlan {
    pub launches: Vec<Launch>,
    /// Requests drained this round (== sum of launch entries).
    pub drained: usize,
}

/// A scheduling policy over the admission queues.
pub trait Scheduler: Send {
    /// Drain work for one round and plan launches.
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan;

    fn label(&self) -> &'static str;

    /// Batcher statistics if the policy batches (SpaceTime/Exclusive).
    fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        None
    }
}

/// Build the configured scheduler (paper-faithful `PadToBucket` batching,
/// fair drain).
pub fn make_scheduler(
    kind: SchedulerKind,
    buckets: Vec<usize>,
    max_batch: usize,
) -> Box<dyn Scheduler> {
    make_scheduler_with_policy(kind, buckets, max_batch, PaddingPolicy::PadToBucket, false)
}

/// Build the configured scheduler with explicit padding policy and
/// SLO-aware drain (space-time only — the other policies define their own
/// drain order).
pub fn make_scheduler_with_policy(
    kind: SchedulerKind,
    buckets: Vec<usize>,
    max_batch: usize,
    policy: PaddingPolicy,
    slo_aware: bool,
) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Exclusive => {
            Box::new(ExclusiveSched::with_policy(buckets, max_batch, policy))
        }
        SchedulerKind::TimeMux => Box::new(TimeMuxSched::new(buckets)),
        SchedulerKind::SpaceMux => Box::new(SpaceMuxSched::new(buckets)),
        SchedulerKind::SpaceTime => Box::new(
            SpaceTimeSched::with_policy(buckets, max_batch, policy).slo_aware(slo_aware),
        ),
    }
}

/// Drain up to `cap` requests from one tenant's queue.
fn drain_tenant(queues: &mut QueueSet, tenant: usize, cap: usize) -> Vec<InferenceRequest> {
    let mut out = Vec::new();
    while out.len() < cap {
        match queues.pop_tenant(tenant) {
            Some(r) => out.push(r),
            None => break,
        }
    }
    out
}

/// Single-problem launches (used by the time/space baselines): each request
/// becomes its own r=1 launch (smallest bucket).
fn singleton_launches(reqs: Vec<InferenceRequest>, bucket1: usize) -> Vec<Launch> {
    reqs.into_iter()
        .map(|r| Launch { class: r.class, entries: vec![r], r_bucket: bucket1 })
        .collect()
}

// ---------------------------------------------------------------------------

/// Exclusive access: one tenant owns the device per round.
pub struct ExclusiveSched {
    batcher: DynamicBatcher,
    next_tenant: usize,
}

impl ExclusiveSched {
    pub fn new(buckets: Vec<usize>, max_batch: usize) -> Self {
        Self::with_policy(buckets, max_batch, PaddingPolicy::PadToBucket)
    }

    pub fn with_policy(buckets: Vec<usize>, max_batch: usize, policy: PaddingPolicy) -> Self {
        Self {
            batcher: DynamicBatcher::with_policy(buckets, max_batch, policy),
            next_tenant: 0,
        }
    }
}

impl Scheduler for ExclusiveSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        let n = queues.n_tenants();
        if n == 0 {
            return RoundPlan::default();
        }
        // Rotate to the next backlogged tenant.
        for i in 0..n {
            let t = (self.next_tenant + i) % n;
            if queues.tenant(t).map_or(false, |q| !q.is_empty()) {
                self.next_tenant = (t + 1) % n;
                let reqs = drain_tenant(queues, t, self.batcher.max_batch());
                let drained = reqs.len();
                return RoundPlan { launches: self.batcher.plan(reqs), drained };
            }
        }
        RoundPlan::default()
    }

    fn label(&self) -> &'static str {
        "exclusive"
    }

    fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        Some(self.batcher.stats)
    }
}

// ---------------------------------------------------------------------------

/// Time multiplexing: round-robin, one problem per context quantum.
pub struct TimeMuxSched {
    bucket1: usize,
    next_tenant: usize,
}

impl TimeMuxSched {
    pub fn new(buckets: Vec<usize>) -> Self {
        let bucket1 = buckets.iter().copied().min().unwrap_or(1);
        Self { bucket1, next_tenant: 0 }
    }
}

impl Scheduler for TimeMuxSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        let n = queues.n_tenants();
        if n == 0 {
            return RoundPlan::default();
        }
        for i in 0..n {
            let t = (self.next_tenant + i) % n;
            if queues.tenant(t).map_or(false, |q| !q.is_empty()) {
                self.next_tenant = (t + 1) % n;
                let reqs = drain_tenant(queues, t, 1);
                let drained = reqs.len();
                return RoundPlan {
                    launches: singleton_launches(reqs, self.bucket1),
                    drained,
                };
            }
        }
        RoundPlan::default()
    }

    fn label(&self) -> &'static str {
        "time-mux"
    }
}

// ---------------------------------------------------------------------------

/// Spatial multiplexing: every backlogged tenant gets a stream slot per
/// round; each problem is still its own kernel launch.
pub struct SpaceMuxSched {
    bucket1: usize,
}

impl SpaceMuxSched {
    pub fn new(buckets: Vec<usize>) -> Self {
        let bucket1 = buckets.iter().copied().min().unwrap_or(1);
        Self { bucket1 }
    }
}

impl Scheduler for SpaceMuxSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        let mut reqs = Vec::new();
        for t in queues.backlogged() {
            reqs.extend(drain_tenant(queues, t, 1));
        }
        let drained = reqs.len();
        RoundPlan { launches: singleton_launches(reqs, self.bucket1), drained }
    }

    fn label(&self) -> &'static str {
        "space-mux"
    }
}

// ---------------------------------------------------------------------------

/// Space-time scheduling (the paper's contribution): drain across tenants
/// and fuse same-class problems into super-kernels.
///
/// Two drain orders:
/// * **fair** (default): rotate across backlogged tenants one request per
///   pass — equal shares of every launch.
/// * **SLO-aware** (`slo_aware(true)`): per pass, visit backlogged tenants
///   by their head-of-queue *deadline* (arrival + tenant SLO), earliest
///   first — the paper's §4.1 "determine when to execute workloads based
///   on per-model SLOs". Urgent tenants get the early lanes and, when the
///   cap splits a round, the earlier launch.
pub struct SpaceTimeSched {
    batcher: DynamicBatcher,
    slo_aware: bool,
}

impl SpaceTimeSched {
    pub fn new(buckets: Vec<usize>, max_batch: usize) -> Self {
        Self::with_policy(buckets, max_batch, PaddingPolicy::PadToBucket)
    }

    pub fn with_policy(buckets: Vec<usize>, max_batch: usize, policy: PaddingPolicy) -> Self {
        Self {
            batcher: DynamicBatcher::with_policy(buckets, max_batch, policy),
            slo_aware: false,
        }
    }

    pub fn slo_aware(mut self, on: bool) -> Self {
        self.slo_aware = on;
        self
    }
}

impl Scheduler for SpaceTimeSched {
    fn plan_round(&mut self, queues: &mut QueueSet) -> RoundPlan {
        let cap = self.batcher.max_batch();
        let mut reqs = Vec::new();
        if self.slo_aware {
            // Request-level EDF: repeatedly pop the globally earliest
            // head-of-queue deadline (queues are FIFO per tenant, so the
            // head is each tenant's most urgent request).
            while reqs.len() < cap {
                let next = queues
                    .backlogged()
                    .into_iter()
                    .min_by_key(|&t| {
                        queues.tenant(t).and_then(|q| q.peek()).map(|r| r.deadline)
                    });
                let Some(t) = next else { break };
                if let Some(r) = queues.pop_tenant(t) {
                    reqs.push(r);
                }
            }
        } else {
            // Fair drain: rotate across backlogged tenants taking one
            // request each until the cap or empty queues.
            'outer: loop {
                let backlogged = queues.backlogged();
                if backlogged.is_empty() {
                    break;
                }
                let mut took = false;
                for t in backlogged {
                    if reqs.len() >= cap {
                        break 'outer;
                    }
                    if let Some(r) = queues.pop_tenant(t) {
                        reqs.push(r);
                        took = true;
                    }
                }
                if !took {
                    break;
                }
            }
        }
        let drained = reqs.len();
        RoundPlan { launches: self.batcher.plan(reqs), drained }
    }

    fn label(&self) -> &'static str {
        "space-time"
    }

    fn batcher_stats(&self) -> Option<crate::coordinator::batcher::BatcherStats> {
        Some(self.batcher.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ShapeClass;
    use std::time::Instant;

    fn fill(queues: &mut QueueSet, tenant: usize, n: usize, class: ShapeClass) {
        for i in 0..n {
            queues
                .push(InferenceRequest {
                    id: (tenant * 1000 + i) as u64,
                    tenant,
                    class,
                    payload: vec![],
                    arrived: Instant::now(),
            deadline: Instant::now(),
                })
                .unwrap();
        }
    }

    fn buckets() -> Vec<usize> {
        vec![1, 2, 4, 8, 16, 32, 64]
    }

    const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 64, n: 64, k: 64 };

    #[test]
    fn spacetime_fuses_across_tenants_one_launch() {
        let mut q = QueueSet::new(4, 16);
        for t in 0..4 {
            fill(&mut q, t, 2, CLASS);
        }
        let mut s = SpaceTimeSched::new(buckets(), 64);
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.drained, 8);
        assert_eq!(plan.launches.len(), 1, "8 same-class problems -> 1 launch");
        assert_eq!(plan.launches[0].r_bucket, 8);
        assert!(q.is_empty());
    }

    #[test]
    fn spacetime_fair_drain_interleaves_tenants() {
        let mut q = QueueSet::new(2, 16);
        fill(&mut q, 0, 3, CLASS);
        fill(&mut q, 1, 3, CLASS);
        let mut s = SpaceTimeSched::new(buckets(), 4);
        let plan = s.plan_round(&mut q);
        // cap 4 -> fair drain takes 2 from each tenant; lanes are then
        // canonicalized (sorted by tenant) for fusion-cache stability.
        let tenants: Vec<usize> =
            plan.launches[0].entries.iter().map(|e| e.tenant).collect();
        assert_eq!(tenants, vec![0, 0, 1, 1]);
        assert_eq!(q.total_pending(), 2);
        // Fairness is about WHAT was drained, not lane order: each tenant
        // keeps exactly one leftover request.
        assert_eq!(q.tenant(0).unwrap().len(), 1);
        assert_eq!(q.tenant(1).unwrap().len(), 1);
    }

    #[test]
    fn timemux_one_problem_per_round_rotates() {
        let mut q = QueueSet::new(3, 16);
        for t in 0..3 {
            fill(&mut q, t, 1, CLASS);
        }
        let mut s = TimeMuxSched::new(buckets());
        let mut order = Vec::new();
        for _ in 0..3 {
            let plan = s.plan_round(&mut q);
            assert_eq!(plan.launches.len(), 1);
            assert_eq!(plan.launches[0].entries.len(), 1);
            assert_eq!(plan.launches[0].r_bucket, 1);
            order.push(plan.launches[0].entries[0].tenant);
        }
        assert_eq!(order, vec![0, 1, 2], "strict round-robin");
        assert!(s.plan_round(&mut q).launches.is_empty());
    }

    #[test]
    fn timemux_skips_idle_tenants() {
        let mut q = QueueSet::new(3, 16);
        fill(&mut q, 1, 2, CLASS);
        let mut s = TimeMuxSched::new(buckets());
        assert_eq!(s.plan_round(&mut q).launches[0].entries[0].tenant, 1);
        assert_eq!(s.plan_round(&mut q).launches[0].entries[0].tenant, 1);
    }

    #[test]
    fn spacemux_one_launch_per_backlogged_tenant() {
        let mut q = QueueSet::new(4, 16);
        fill(&mut q, 0, 2, CLASS);
        fill(&mut q, 2, 1, CLASS);
        let mut s = SpaceMuxSched::new(buckets());
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.launches.len(), 2, "tenants 0 and 2");
        assert!(plan.launches.iter().all(|l| l.entries.len() == 1));
        let plan2 = s.plan_round(&mut q);
        assert_eq!(plan2.launches.len(), 1, "only tenant 0 still backlogged");
    }

    #[test]
    fn exclusive_serves_single_tenant_batched() {
        let mut q = QueueSet::new(2, 16);
        fill(&mut q, 0, 3, CLASS);
        fill(&mut q, 1, 5, CLASS);
        let mut s = ExclusiveSched::new(buckets(), 64);
        let p0 = s.plan_round(&mut q);
        assert_eq!(p0.launches.len(), 1);
        assert!(p0.launches[0].entries.iter().all(|e| e.tenant == 0));
        assert_eq!(p0.drained, 3);
        let p1 = s.plan_round(&mut q);
        assert!(p1.launches[0].entries.iter().all(|e| e.tenant == 1));
        assert_eq!(p1.drained, 5);
    }

    #[test]
    fn slo_aware_drains_urgent_tenant_into_first_launch() {
        use std::time::Duration;
        let mut q = QueueSet::new(3, 16);
        let now = Instant::now();
        // Tenant 2 has the tightest deadline, tenant 0 the loosest.
        for (tenant, slo_ms) in [(0usize, 300u64), (1, 200), (2, 50)] {
            for i in 0..2 {
                q.push(InferenceRequest {
                    id: (tenant * 10 + i) as u64,
                    tenant,
                    class: CLASS,
                    payload: vec![],
                    arrived: now,
                    deadline: now + Duration::from_millis(slo_ms),
                })
                .unwrap();
            }
        }
        // Cap 2: only one tenant's worth per pass fits the first launch.
        let mut s = SpaceTimeSched::new(buckets(), 2).slo_aware(true);
        let plan = s.plan_round(&mut q);
        let first = &plan.launches[0];
        assert!(
            first.entries.iter().all(|e| e.tenant == 2),
            "tightest-SLO tenant must fill the first launch, got {:?}",
            first.entries.iter().map(|e| e.tenant).collect::<Vec<_>>()
        );
        // Fair drain (default) would have taken one from each tenant.
        let mut q2 = QueueSet::new(3, 16);
        for (tenant, slo_ms) in [(0usize, 300u64), (1, 200), (2, 50)] {
            q2.push(InferenceRequest {
                id: tenant as u64,
                tenant,
                class: CLASS,
                payload: vec![],
                arrived: now,
                deadline: now + Duration::from_millis(slo_ms),
            })
            .unwrap();
        }
        let mut fair = SpaceTimeSched::new(buckets(), 2);
        let plan2 = fair.plan_round(&mut q2);
        let tenants: Vec<usize> =
            plan2.launches[0].entries.iter().map(|e| e.tenant).collect();
        assert_eq!(tenants, vec![0, 1], "fair drain visits ascending ids");
    }

    #[test]
    fn make_scheduler_labels() {
        use crate::config::SchedulerKind::*;
        for (k, l) in [
            (Exclusive, "exclusive"),
            (TimeMux, "time-mux"),
            (SpaceMux, "space-mux"),
            (SpaceTime, "space-time"),
        ] {
            assert_eq!(make_scheduler(k, buckets(), 8).label(), l);
        }
    }

    #[test]
    fn empty_queues_empty_plan() {
        let mut q = QueueSet::new(2, 4);
        for kind in [
            make_scheduler(crate::config::SchedulerKind::SpaceTime, buckets(), 8),
            make_scheduler(crate::config::SchedulerKind::TimeMux, buckets(), 8),
        ]
        .iter_mut()
        {
            let plan = kind.plan_round(&mut q);
            assert_eq!(plan.drained, 0);
            assert!(plan.launches.is_empty());
        }
    }
}
