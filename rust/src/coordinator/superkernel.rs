//! Super-kernel assembly and execution: gather R problems' operands into
//! the batched layout, execute the matching AOT artifact once, scatter the
//! R output slices back to their requests.
//!
//! This is the paper's `cublasSgemmBatched` dispatch point. Two caches keep
//! the steady-state launch cheap:
//! * the engine's executable cache — compile once per (kind, shape, R);
//! * the [`FusionCache`] — device-resident stacked *weight* operands per
//!   recurring lane assignment (paper §4: "overheads gradually decrease if
//!   we cache super-kernels as workloads stabilize"), so a hot launch
//!   uploads only activations.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::Launch;
use crate::coordinator::fusion_cache::{FusionCache, FusionKey, WeightSet};
use crate::coordinator::tenant::{ModelSpec, TenantRegistry};
use crate::runtime::{HostTensor, PjrtEngine};
use crate::util::sync::lock_recover;

/// Which artifact flavor the dispatcher executes. `Xla` is the fast
/// CPU-PJRT lowering used by the serving benches; `Pallas` routes through
/// the L1 kernel (identical math, carries the TPU BlockSpec structure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    Xla,
    Pallas,
}

impl Flavor {
    pub fn as_str(&self) -> &'static str {
        match self {
            Flavor::Xla => "xla",
            Flavor::Pallas => "pallas",
        }
    }
}

/// Result of one super-kernel execution: per-entry output slices plus
/// timing shared by every fused problem.
#[derive(Debug)]
pub struct LaunchResult {
    /// One output per launch entry, in entry order.
    pub outputs: Vec<HostTensor>,
    /// Wall time inside the executable (gather/scatter excluded), seconds.
    pub service_s: f64,
    /// Gather + upload + scatter overhead, seconds.
    pub marshal_s: f64,
    pub r_bucket: usize,
}

/// Positional operand roles for a graph kind, matching the builders in
/// `python/compile/model.py`.
///
/// * `batched_gemm`: (a, b) — both request payload.
/// * `mlp_block`:    (x, w1, b1, w2) — x payload, rest tenant weights.
/// * `rnn_cell`:     (w_ih, w_hh, x, h) — weights first, payload last.
fn weight_positions(kind: &str) -> &'static [usize] {
    match kind {
        "mlp_block" => &[1, 2, 3],
        "fused_linear" => &[1, 2],
        "rnn_cell" => &[0, 1],
        _ => &[],
    }
}

/// The dispatcher: resolves (launch, tenants) to an artifact + operands.
pub struct SuperKernelExec<'e> {
    engine: &'e PjrtEngine,
    flavor: Flavor,
}

impl<'e> SuperKernelExec<'e> {
    pub fn new(engine: &'e PjrtEngine, flavor: Flavor) -> Self {
        Self { engine, flavor }
    }

    pub fn flavor(&self) -> Flavor {
        self.flavor
    }

    /// Artifact name for (kind, shape class, exact R bucket).
    fn artifact_name(&self, launch: &Launch) -> Result<String> {
        let class = launch.class;
        let info = self
            .engine
            .manifest()
            .find(
                class.kind,
                self.flavor.as_str(),
                class.mnk(),
                launch.r_bucket,
            )
            .or_else(|| {
                // Kinds with a single shape class (mlp_block, fused_linear,
                // rnn_cell) are looked up by (kind, r) alone. batched_gemm
                // has many shape classes — never shape-blind there.
                if class.kind == "batched_gemm" {
                    return None;
                }
                self.engine
                    .manifest()
                    .find(class.kind, self.flavor.as_str(), (0, 0, 0), launch.r_bucket)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for {class} r={} flavor={}",
                    launch.r_bucket,
                    self.flavor.as_str()
                )
            })?;
        Ok(info.name.clone())
    }

    /// Stack one *activation* operand column from the launch payloads.
    fn stack_activations(
        launch: &Launch,
        payload_idx: usize,
        n_payload: usize,
    ) -> Result<HostTensor> {
        let mut col = Vec::with_capacity(launch.entries.len());
        for e in &launch.entries {
            if e.payload.len() != n_payload {
                return Err(anyhow!(
                    "request {} has {} payload tensors, expected {n_payload}",
                    e.id,
                    e.payload.len()
                ));
            }
            col.push(&e.payload[payload_idx]);
        }
        Ok(HostTensor::stack(&col, launch.r_bucket))
    }

    /// Stack the *weight* operand columns from the tenant registry, in
    /// operand-position order (the FusionCache build path).
    fn stack_weights(
        launch: &Launch,
        tenants: &TenantRegistry,
        weight_idx: &[usize],
    ) -> Vec<HostTensor> {
        weight_idx
            .iter()
            .enumerate()
            .map(|(wi, _pos)| {
                let col: Vec<&HostTensor> = launch
                    .entries
                    .iter()
                    .map(|e| &tenants.get(e.tenant).expect("tenant").weights[wi])
                    .collect();
                HostTensor::stack(&col, launch.r_bucket)
            })
            .collect()
    }

    /// Activation operands as (position, stacked tensor).
    fn gather_activations(
        &self,
        launch: &Launch,
        spec: &ModelSpec,
    ) -> Result<Vec<(usize, HostTensor)>> {
        Ok(match spec {
            ModelSpec::Sgemm { .. } => vec![
                (0, Self::stack_activations(launch, 0, 2)?),
                (1, Self::stack_activations(launch, 1, 2)?),
            ],
            ModelSpec::Mlp { .. } | ModelSpec::FusedLinear { .. } => {
                vec![(0, Self::stack_activations(launch, 0, 1)?)]
            }
            ModelSpec::RnnCell { .. } => vec![
                (2, Self::stack_activations(launch, 0, 2)?),
                (3, Self::stack_activations(launch, 1, 2)?),
            ],
        })
    }

    /// Resolve a launch's device-resident weight operands through the
    /// fusion cache, building them (host gather + device upload) on a
    /// miss. `None` for weight-less kinds (raw batched GEMM).
    ///
    /// This is the **marshal half** of a launch, split out so the
    /// pipelined driver can run it at dispatch time — overlapping round
    /// N+1's weight uploads with round N's execution on the lane workers —
    /// while the workers execute via [`SuperKernelExec::execute_prepared`]
    /// without ever touching the cache or the registry. The lock covers
    /// only the map lookup/insert; a cold build runs outside it, and a
    /// racing duplicate build is dropped at `insert` (first entry wins).
    pub fn resolve_weights(
        engine: &PjrtEngine,
        launch: &Launch,
        tenants: &TenantRegistry,
        cache: &Mutex<FusionCache>,
    ) -> Result<Option<Arc<WeightSet>>> {
        let w_pos = weight_positions(launch.class.kind);
        if w_pos.is_empty() {
            return Ok(None);
        }
        let key = FusionKey::of(launch);
        if let Some(w) = lock_recover(cache).get(&key) {
            return Ok(Some(w));
        }
        let host = Self::stack_weights(launch, tenants, w_pos);
        let buffers = host
            .iter()
            .map(|t| engine.to_device(t))
            .collect::<Result<Vec<_>>>()?;
        let built = Arc::new(WeightSet::new(buffers));
        Ok(Some(lock_recover(cache).insert(key, built)))
    }

    /// Execute a launch: gather → ONE PJRT execution → scatter.
    ///
    /// Single-owner convenience over [`SuperKernelExec::resolve_weights`]
    /// plus [`SuperKernelExec::execute_prepared`]; the pipelined driver
    /// calls the halves separately so weight marshaling overlaps the
    /// previous round's execution.
    pub fn execute(
        &self,
        launch: &Launch,
        tenants: &TenantRegistry,
        cache: &Mutex<FusionCache>,
    ) -> Result<LaunchResult> {
        let first = launch
            .entries
            .first()
            .ok_or_else(|| anyhow!("empty launch"))?;
        let spec = tenants
            .get(first.tenant)
            .ok_or_else(|| anyhow!("unknown tenant {}", first.tenant))?
            .spec
            .clone();
        let weights = Self::resolve_weights(self.engine, launch, tenants, cache)?;
        self.execute_prepared(launch, &spec, weights.as_deref())
    }

    /// The **execution half**: run a launch whose weight operands are
    /// already device-resident. Needs no registry or cache access — this
    /// is what a persistent lane worker runs. `marshal_s` here covers the
    /// activation gather/upload and output scatter; the weight upload
    /// happens on the driver thread at dispatch, which times it and ships
    /// it along (`lanepool::WorkItem::weights_marshal_s`) so the
    /// completion's total marshal time still covers the whole launch
    /// cost.
    pub fn execute_prepared(
        &self,
        launch: &Launch,
        spec: &ModelSpec,
        weights: Option<&WeightSet>,
    ) -> Result<LaunchResult> {
        let name = self.artifact_name(launch)?;
        let exe = self.engine.load(&name)?;
        if launch.entries.is_empty() {
            return Err(anyhow!("empty launch"));
        }
        let kind = launch.class.kind;
        let w_pos = weight_positions(kind);
        let n_operands = exe.info.inputs.len();

        let t0 = Instant::now();
        // Host gather + upload of activations.
        let acts = self.gather_activations(launch, spec)?;
        let act_buffers: Vec<(usize, xla::PjRtBuffer)> = acts
            .iter()
            .map(|(pos, t)| Ok((*pos, self.engine.to_device(t)?)))
            .collect::<Result<_>>()?;
        // Assemble positional operand list.
        let mut slots: Vec<Option<&xla::PjRtBuffer>> = vec![None; n_operands];
        for (pos, buf) in &act_buffers {
            slots[*pos] = Some(buf);
        }
        if let Some(ws) = weights {
            for (wi, pos) in w_pos.iter().enumerate() {
                slots[*pos] = Some(&ws.buffers()[wi]);
            }
        }
        let operands: Vec<&xla::PjRtBuffer> = slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| anyhow!("{name}: operand {i} unset")))
            .collect::<Result<_>>()?;

        let t1 = Instant::now();
        let out = exe.execute_buffers(&operands)?;
        let t2 = Instant::now();
        let batched = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{name}: empty result tuple"))?;
        let outputs: Vec<HostTensor> = (0..launch.entries.len())
            .map(|i| batched.slice_problem(i))
            .collect();
        let t3 = Instant::now();
        Ok(LaunchResult {
            outputs,
            service_s: (t2 - t1).as_secs_f64(),
            marshal_s: (t1 - t0).as_secs_f64() + (t3 - t2).as_secs_f64(),
            r_bucket: launch.r_bucket,
        })
    }
}

#[cfg(test)]
mod tests {
    // Execution tests require artifacts; they live in
    // rust/tests/integration_coordinator.rs. Here: pure plumbing.
    use super::*;

    #[test]
    fn flavor_strings() {
        assert_eq!(Flavor::Xla.as_str(), "xla");
        assert_eq!(Flavor::Pallas.as_str(), "pallas");
    }

    #[test]
    fn weight_positions_per_kind() {
        assert_eq!(weight_positions("batched_gemm"), &[] as &[usize]);
        assert_eq!(weight_positions("mlp_block"), &[1, 2, 3]);
        assert_eq!(weight_positions("rnn_cell"), &[0, 1]);
    }
}
