//! Request/response types flowing through the coordinator.

use std::time::Instant;

use crate::runtime::HostTensor;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// The GEMM problem class a request belongs to: requests fuse into one
/// super-kernel only if their (kind, m, n, k) match — the
/// `cublasSgemmBatched` constraint the paper works under (§4.1), with
/// MAGMA-style variable-size batching emulated by bucketing + padding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeClass {
    /// Graph kind: `batched_gemm`, `fused_linear`, `mlp_block`, `rnn_cell`.
    pub kind: &'static str,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl ShapeClass {
    pub fn batched_gemm(m: usize, n: usize, k: usize) -> Self {
        Self { kind: "batched_gemm", m, n, k }
    }

    pub fn mlp_block(m: usize, hidden: usize, k: usize, n_out: usize) -> Self {
        // `hidden` folds into the artifact lookup via the fixed MLP geometry;
        // the class key only needs (m, n, k) + kind to be collision-free for
        // the shapes aot.py lowers.
        let _ = hidden;
        Self { kind: "mlp_block", m, n: n_out, k }
    }

    pub fn fused_linear(m: usize, n: usize, k: usize) -> Self {
        Self { kind: "fused_linear", m, n, k }
    }

    pub fn rnn_cell(hidden: usize) -> Self {
        Self { kind: "rnn_cell", m: hidden, n: 1, k: hidden }
    }

    pub fn mnk(&self) -> (usize, usize, usize) {
        (self.m, self.n, self.k)
    }

    /// FLOPs of ONE problem of this class (per super-kernel lane).
    pub fn flops(&self) -> f64 {
        let base = 2.0 * (self.m * self.n * self.k) as f64;
        match self.kind {
            "rnn_cell" => 2.0 * 2.0 * (self.m * self.k) as f64, // two matvecs
            "mlp_block" => base * 2.0, // two GEMMs of comparable size
            _ => base,
        }
    }
}

impl std::fmt::Display for ShapeClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}x{}x{}", self.kind, self.m, self.n, self.k)
    }
}

/// One inference request: a single problem instance for one tenant.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub tenant: usize,
    pub class: ShapeClass,
    /// Request payload (activations). Weights live in the tenant registry.
    /// For `batched_gemm`: [a, b] each `[m,k]` / `[k,n]`.
    /// For `mlp_block`/`fused_linear`: `[x]` `[m,k]`;
    /// for `rnn_cell`: [x, h] `[hidden,1]`.
    pub payload: Vec<HostTensor>,
    pub arrived: Instant,
    /// SLO deadline (`arrived + tenant slo`). Drives the SLO-aware drain
    /// order (paper §4.1: "determine when to execute workloads based on
    /// per-model SLOs").
    pub deadline: Instant,
}

/// Completion record handed back to the caller.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: RequestId,
    pub tenant: usize,
    pub output: HostTensor,
    /// End-to-end latency (arrival -> completion), seconds.
    pub latency_s: f64,
    /// Time spent inside the PJRT executable, seconds.
    pub service_s: f64,
    /// How many problems shared the launch that produced this response.
    pub fused_r: usize,
}

/// Terminal failure for a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Reject {
    /// This tenant's admission queue is full (per-tenant backpressure).
    QueueFull,
    /// The coordinator's global admission cap is hit: load shed across the
    /// board (the 429-style outcome an oversubscribed bounded front emits
    /// instead of growing without bound).
    Overloaded,
    /// Tenant was evicted by the straggler monitor.
    TenantEvicted,
    /// Admission-time deadline check failed: even an immediate, minimal
    /// launch of this request's shape class is predicted (by the
    /// [`crate::coordinator::costmodel::CostModel`]) to complete after the
    /// request's SLO deadline. Shedding at admission is strictly better
    /// than queueing work that is already lost (DARIS-style deadline-aware
    /// admission, arXiv:2504.08795).
    DeadlineInfeasible,
    /// Tenant unknown / shape not servable.
    BadRequest(String),
}

impl Reject {
    /// HTTP-style status code the serving frontend surfaces.
    pub fn http_status(&self) -> u16 {
        match self {
            Reject::QueueFull | Reject::Overloaded => 429,
            Reject::TenantEvicted => 503,
            Reject::DeadlineInfeasible => 504,
            Reject::BadRequest(_) => 400,
        }
    }
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::QueueFull => write!(f, "queue full"),
            Reject::Overloaded => write!(f, "overloaded: global admission cap reached"),
            Reject::TenantEvicted => write!(f, "tenant evicted"),
            Reject::DeadlineInfeasible => {
                write!(f, "deadline infeasible: predicted completion exceeds SLO deadline")
            }
            Reject::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_class_key_discriminates() {
        let a = ShapeClass::batched_gemm(256, 128, 1152);
        let b = ShapeClass::batched_gemm(256, 128, 1153);
        let c = ShapeClass::rnn_cell(256);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, ShapeClass::batched_gemm(256, 128, 1152));
    }

    #[test]
    fn flops_positive_and_kind_scaled() {
        let g = ShapeClass::batched_gemm(256, 256, 256);
        assert_eq!(g.flops(), 2.0 * 256.0 * 256.0 * 256.0);
        let r = ShapeClass::rnn_cell(512);
        assert_eq!(r.flops(), 4.0 * 512.0 * 512.0);
        let m = ShapeClass::mlp_block(8, 512, 256, 256);
        assert!(m.flops() > 0.0);
    }

    #[test]
    fn display_is_compact() {
        let s = ShapeClass::batched_gemm(256, 128, 1152).to_string();
        assert_eq!(s, "batched_gemm:256x128x1152");
    }

    #[test]
    fn reject_http_status_codes() {
        assert_eq!(Reject::QueueFull.http_status(), 429);
        assert_eq!(Reject::Overloaded.http_status(), 429);
        assert_eq!(Reject::TenantEvicted.http_status(), 503);
        assert_eq!(Reject::DeadlineInfeasible.http_status(), 504);
        assert_eq!(Reject::BadRequest("x".into()).http_status(), 400);
        assert!(Reject::Overloaded.to_string().contains("overloaded"));
        assert!(Reject::DeadlineInfeasible.to_string().contains("deadline"));
    }
}
