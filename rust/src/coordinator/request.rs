//! Request/response types flowing through the coordinator, plus the
//! client-facing request context and the structured rejection API.
//!
//! The [`RequestContext`] is the unit of the gateway redesign: what used
//! to travel as a bare `(tenant, payload)` tuple — with the deadline
//! silently re-derived from config defaults at admission — is now an
//! explicit `{ tenant, deadline, priority, trace_id }` record carried
//! from the wire all the way into the EDF queues. The deadline the heap
//! orders by is the deadline the client supplied (or the tenant's SLO
//! only when the client supplied none), so wire deadlines are honored
//! end-to-end.
//!
//! [`Reject`] is the matching structured error API: every rejection has a
//! machine-readable [`RejectKind`], an optional `retry_after` hint, and —
//! for gateway-originated sheds — shard/breaker provenance.
//! [`Reject::http_status`] remains as a thin compatibility shim for
//! embedders that still speak status codes.

use std::time::{Duration, Instant};

use crate::runtime::HostTensor;
use crate::util::json::Json;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// The GEMM problem class a request belongs to: requests fuse into one
/// super-kernel only if their (kind, m, n, k) match — the
/// `cublasSgemmBatched` constraint the paper works under (§4.1), with
/// MAGMA-style variable-size batching emulated by bucketing + padding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeClass {
    /// Graph kind: `batched_gemm`, `fused_linear`, `mlp_block`, `rnn_cell`.
    pub kind: &'static str,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl ShapeClass {
    pub fn batched_gemm(m: usize, n: usize, k: usize) -> Self {
        Self { kind: "batched_gemm", m, n, k }
    }

    pub fn mlp_block(m: usize, hidden: usize, k: usize, n_out: usize) -> Self {
        // `hidden` folds into the artifact lookup via the fixed MLP geometry;
        // the class key only needs (m, n, k) + kind to be collision-free for
        // the shapes aot.py lowers.
        let _ = hidden;
        Self { kind: "mlp_block", m, n: n_out, k }
    }

    pub fn fused_linear(m: usize, n: usize, k: usize) -> Self {
        Self { kind: "fused_linear", m, n, k }
    }

    pub fn rnn_cell(hidden: usize) -> Self {
        Self { kind: "rnn_cell", m: hidden, n: 1, k: hidden }
    }

    pub fn mnk(&self) -> (usize, usize, usize) {
        (self.m, self.n, self.k)
    }

    /// FLOPs of ONE problem of this class (per super-kernel lane).
    pub fn flops(&self) -> f64 {
        let base = 2.0 * (self.m * self.n * self.k) as f64;
        match self.kind {
            "rnn_cell" => 2.0 * 2.0 * (self.m * self.k) as f64, // two matvecs
            "mlp_block" => base * 2.0, // two GEMMs of comparable size
            _ => base,
        }
    }
}

impl std::fmt::Display for ShapeClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}x{}x{}", self.kind, self.m, self.n, self.k)
    }
}

/// Scheduling priority class carried by every request. Deadline remains
/// the primary EDF key; priority breaks deadline ties (then insertion
/// order breaks priority ties), so two requests due at the same instant
/// pop urgent-first instead of arrival-first.
///
/// The derived `Ord` follows declaration order: `High < Normal < Batch`,
/// i.e. "smaller sorts more urgent" — the same convention as deadlines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Latency-critical: wins EDF ties, first through gateway admission.
    High,
    /// The default interactive class.
    #[default]
    Normal,
    /// Throughput-oriented background work: loses ties, sheds first.
    Batch,
}

impl Priority {
    /// Tie-break rank used by the EDF heaps (0 is most urgent).
    pub fn rank(self) -> u8 {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Batch => 2,
        }
    }

    /// Wire/config name.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Batch => "batch",
        }
    }

    /// Parse a wire/config name (`high` / `normal` / `batch`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// How a request's completion deadline is specified on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeadlineSpec {
    /// No wire deadline: fall back to the tenant's configured SLO (the
    /// pre-redesign behaviour, now an explicit default instead of the
    /// only option).
    #[default]
    SloDefault,
    /// Absolute completion deadline.
    At(Instant),
    /// Relative budget from the arrival instant.
    Budget(Duration),
}

/// The client-facing request context: everything the caller asserts about
/// a request besides its payload. Replaces the bare `(tenant, payload)`
/// tuple; built by the gateway from the authenticated principal + wire
/// fields, or by [`RequestContext::new`] for the compatibility path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestContext {
    pub tenant: usize,
    pub deadline: DeadlineSpec,
    pub priority: Priority,
    /// Opaque caller-chosen correlation id, echoed on the response.
    pub trace_id: u64,
}

impl RequestContext {
    /// The default context the deprecated `(tenant, payload)` signature
    /// builds: SLO-default deadline, normal priority, trace id 0.
    pub fn new(tenant: usize) -> Self {
        Self {
            tenant,
            deadline: DeadlineSpec::SloDefault,
            priority: Priority::Normal,
            trace_id: 0,
        }
    }

    pub fn with_deadline(mut self, at: Instant) -> Self {
        self.deadline = DeadlineSpec::At(at);
        self
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.deadline = DeadlineSpec::Budget(budget);
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_trace_id(mut self, trace_id: u64) -> Self {
        self.trace_id = trace_id;
        self
    }

    /// The absolute deadline this context resolves to for a request that
    /// arrived at `arrived`, given the tenant's configured SLO. This is
    /// THE deadline the EDF heaps order by — there is no other
    /// derivation site.
    pub fn resolve_deadline(&self, arrived: Instant, slo_default: Duration) -> Instant {
        match self.deadline {
            DeadlineSpec::SloDefault => arrived + slo_default,
            DeadlineSpec::At(at) => at,
            DeadlineSpec::Budget(budget) => arrived + budget,
        }
    }

    /// Materialize the concrete [`InferenceRequest`] the queues hold.
    pub fn into_request(
        self,
        id: RequestId,
        class: ShapeClass,
        payload: Vec<HostTensor>,
        arrived: Instant,
        slo_default: Duration,
    ) -> InferenceRequest {
        InferenceRequest {
            id,
            tenant: self.tenant,
            class,
            payload,
            arrived,
            deadline: self.resolve_deadline(arrived, slo_default),
            priority: self.priority,
            trace_id: self.trace_id,
        }
    }
}

/// One inference request: a single problem instance for one tenant.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub tenant: usize,
    pub class: ShapeClass,
    /// Request payload (activations). Weights live in the tenant registry.
    /// For `batched_gemm`: [a, b] each `[m,k]` / `[k,n]`.
    /// For `mlp_block`/`fused_linear`: `[x]` `[m,k]`;
    /// for `rnn_cell`: [x, h] `[hidden,1]`.
    pub payload: Vec<HostTensor>,
    pub arrived: Instant,
    /// Absolute completion deadline, resolved by
    /// [`RequestContext::resolve_deadline`] — the wire deadline when one
    /// was supplied, `arrived + tenant slo` otherwise. Drives the
    /// SLO-aware drain order (paper §4.1: "determine when to execute
    /// workloads based on per-model SLOs").
    pub deadline: Instant,
    /// EDF tie-break class (carried from the [`RequestContext`]).
    pub priority: Priority,
    /// Correlation id echoed on the response.
    pub trace_id: u64,
}

/// Completion record handed back to the caller.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: RequestId,
    pub tenant: usize,
    pub output: HostTensor,
    /// End-to-end latency (arrival -> completion), seconds.
    pub latency_s: f64,
    /// Time spent inside the PJRT executable, seconds.
    pub service_s: f64,
    /// How many problems shared the launch that produced this response.
    pub fused_r: usize,
    /// Correlation id from the submitting [`RequestContext`].
    pub trace_id: u64,
}

/// Machine-readable rejection kind — the stable vocabulary dashboards and
/// wire clients key on ([`Reject::kind`] / [`RejectKind::as_str`]).
/// Non-exhaustive: new kinds may appear; match with a wildcard arm.
#[non_exhaustive]
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RejectKind {
    QueueFull,
    Overloaded,
    TenantEvicted,
    DeadlineInfeasible,
    BadRequest,
    ServerShutdown,
    RateLimited,
    BreakerOpen,
    AuthFailed,
}

impl RejectKind {
    /// The stable wire name of this kind.
    pub fn as_str(self) -> &'static str {
        match self {
            RejectKind::QueueFull => "queue_full",
            RejectKind::Overloaded => "overloaded",
            RejectKind::TenantEvicted => "tenant_evicted",
            RejectKind::DeadlineInfeasible => "deadline_infeasible",
            RejectKind::BadRequest => "bad_request",
            RejectKind::ServerShutdown => "server_shutdown",
            RejectKind::RateLimited => "rate_limited",
            RejectKind::BreakerOpen => "breaker_open",
            RejectKind::AuthFailed => "auth_failed",
        }
    }
}

/// Where a rejection originated, when a specific device shard is known.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RejectProvenance {
    /// Device shard the rejected request was routed toward.
    pub device: usize,
    /// True when the gateway's circuit breaker shed the request before it
    /// touched any coordinator queue (the shard itself was never asked).
    pub breaker: bool,
}

/// Terminal failure for a request — the structured rejection API.
///
/// Every variant maps to a stable [`RejectKind`]; retry hints and
/// shard/breaker provenance ride the variants that have them
/// ([`Reject::retry_after`], [`Reject::provenance`]). The enum is
/// non-exhaustive: downstream matches need a wildcard arm, which is what
/// lets new admission layers (like the gateway) add outcomes without
/// breaking embedders.
#[non_exhaustive]
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Reject {
    /// This tenant's admission queue is full (per-tenant backpressure).
    QueueFull,
    /// The coordinator's global admission cap is hit: load shed across the
    /// board (the 429-style outcome an oversubscribed bounded front emits
    /// instead of growing without bound).
    Overloaded,
    /// Tenant was evicted by the straggler monitor.
    TenantEvicted,
    /// Admission-time deadline check failed: even an immediate, minimal
    /// launch of this request's shape class is predicted (by the
    /// [`crate::coordinator::costmodel::CostModel`]) to complete after the
    /// request's deadline. Shedding at admission is strictly better
    /// than queueing work that is already lost (DARIS-style deadline-aware
    /// admission, arXiv:2504.08795).
    DeadlineInfeasible,
    /// Tenant unknown / shape not servable / malformed context.
    BadRequest(String),
    /// The serving frontend is stopped: surfaced synchronously at submit
    /// time (a dead server must not hand out receivers that only fail on
    /// `recv`).
    ServerShutdown,
    /// The gateway's per-tenant token bucket is empty; retry once it has
    /// refilled (`retry_after` is the exact refill time at rejection).
    RateLimited { retry_after: Duration },
    /// The circuit breaker for this request's device shard is open: the
    /// shard has been rejecting at a sustained rate and the gateway sheds
    /// without touching coordinator queues until the breaker half-opens
    /// (`retry_after` is the remaining cooldown).
    BreakerOpen { device: usize, retry_after: Duration },
    /// Unknown or missing API key at the gateway.
    AuthFailed,
}

impl Reject {
    /// The machine-readable kind of this rejection.
    pub fn kind(&self) -> RejectKind {
        match self {
            Reject::QueueFull => RejectKind::QueueFull,
            Reject::Overloaded => RejectKind::Overloaded,
            Reject::TenantEvicted => RejectKind::TenantEvicted,
            Reject::DeadlineInfeasible => RejectKind::DeadlineInfeasible,
            Reject::BadRequest(_) => RejectKind::BadRequest,
            Reject::ServerShutdown => RejectKind::ServerShutdown,
            Reject::RateLimited { .. } => RejectKind::RateLimited,
            Reject::BreakerOpen { .. } => RejectKind::BreakerOpen,
            Reject::AuthFailed => RejectKind::AuthFailed,
        }
    }

    /// When to retry, for rejections that carry a concrete hint.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            Reject::RateLimited { retry_after } => Some(*retry_after),
            Reject::BreakerOpen { retry_after, .. } => Some(*retry_after),
            _ => None,
        }
    }

    /// Shard/breaker provenance, for rejections tied to one device shard.
    pub fn provenance(&self) -> Option<RejectProvenance> {
        match self {
            Reject::BreakerOpen { device, .. } => {
                Some(RejectProvenance { device: *device, breaker: true })
            }
            _ => None,
        }
    }

    /// Whether this rejection signals downstream overload pressure — the
    /// outcomes the gateway's circuit breakers trip on.
    pub fn is_overload(&self) -> bool {
        matches!(self, Reject::Overloaded | Reject::DeadlineInfeasible)
    }

    /// HTTP-style status code — kept as a thin compatibility shim over
    /// [`Reject::kind`] for embedders that still speak status codes.
    pub fn http_status(&self) -> u16 {
        match self.kind() {
            RejectKind::QueueFull | RejectKind::Overloaded | RejectKind::RateLimited => 429,
            RejectKind::TenantEvicted | RejectKind::ServerShutdown | RejectKind::BreakerOpen => {
                503
            }
            RejectKind::DeadlineInfeasible => 504,
            RejectKind::AuthFailed => 401,
            _ => 400,
        }
    }

    /// Wire representation: kind + status + message, plus `retry_after_ms`
    /// and `device` when known.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("error", Json::str(self.kind().as_str())),
            ("status", Json::num(self.http_status() as f64)),
            ("message", Json::str(self.to_string())),
        ];
        if let Some(retry) = self.retry_after() {
            pairs.push(("retry_after_ms", Json::num(retry.as_secs_f64() * 1e3)));
        }
        if let Some(p) = self.provenance() {
            pairs.push(("device", Json::num(p.device as f64)));
            pairs.push(("breaker", Json::Bool(p.breaker)));
        }
        Json::obj(pairs)
    }
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::QueueFull => write!(f, "queue full"),
            Reject::Overloaded => write!(f, "overloaded: global admission cap reached"),
            Reject::TenantEvicted => write!(f, "tenant evicted"),
            Reject::DeadlineInfeasible => {
                write!(f, "deadline infeasible: predicted completion exceeds deadline")
            }
            Reject::BadRequest(m) => write!(f, "bad request: {m}"),
            Reject::ServerShutdown => write!(f, "server shut down"),
            Reject::RateLimited { retry_after } => {
                write!(f, "rate limited: retry after {:.1} ms", retry_after.as_secs_f64() * 1e3)
            }
            Reject::BreakerOpen { device, retry_after } => write!(
                f,
                "circuit breaker open for device {device}: retry after {:.1} ms",
                retry_after.as_secs_f64() * 1e3
            ),
            Reject::AuthFailed => write!(f, "authentication failed"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_class_key_discriminates() {
        let a = ShapeClass::batched_gemm(256, 128, 1152);
        let b = ShapeClass::batched_gemm(256, 128, 1153);
        let c = ShapeClass::rnn_cell(256);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, ShapeClass::batched_gemm(256, 128, 1152));
    }

    #[test]
    fn flops_positive_and_kind_scaled() {
        let g = ShapeClass::batched_gemm(256, 256, 256);
        assert_eq!(g.flops(), 2.0 * 256.0 * 256.0 * 256.0);
        let r = ShapeClass::rnn_cell(512);
        assert_eq!(r.flops(), 4.0 * 512.0 * 512.0);
        let m = ShapeClass::mlp_block(8, 512, 256, 256);
        assert!(m.flops() > 0.0);
    }

    #[test]
    fn display_is_compact() {
        let s = ShapeClass::batched_gemm(256, 128, 1152).to_string();
        assert_eq!(s, "batched_gemm:256x128x1152");
    }

    #[test]
    fn reject_http_status_codes() {
        assert_eq!(Reject::QueueFull.http_status(), 429);
        assert_eq!(Reject::Overloaded.http_status(), 429);
        assert_eq!(Reject::TenantEvicted.http_status(), 503);
        assert_eq!(Reject::DeadlineInfeasible.http_status(), 504);
        assert_eq!(Reject::BadRequest("x".into()).http_status(), 400);
        assert_eq!(Reject::ServerShutdown.http_status(), 503);
        assert_eq!(
            Reject::RateLimited { retry_after: Duration::from_millis(5) }.http_status(),
            429
        );
        assert_eq!(
            Reject::BreakerOpen { device: 1, retry_after: Duration::from_millis(9) }
                .http_status(),
            503
        );
        assert_eq!(Reject::AuthFailed.http_status(), 401);
        assert!(Reject::Overloaded.to_string().contains("overloaded"));
        assert!(Reject::DeadlineInfeasible.to_string().contains("deadline"));
    }

    #[test]
    fn reject_kind_and_hints_are_machine_readable() {
        assert_eq!(Reject::Overloaded.kind().as_str(), "overloaded");
        assert_eq!(Reject::AuthFailed.kind(), RejectKind::AuthFailed);
        assert_eq!(Reject::Overloaded.retry_after(), None);
        let rl = Reject::RateLimited { retry_after: Duration::from_millis(12) };
        assert_eq!(rl.retry_after(), Some(Duration::from_millis(12)));
        assert!(rl.provenance().is_none());
        let br = Reject::BreakerOpen { device: 3, retry_after: Duration::from_millis(40) };
        let p = br.provenance().expect("breaker rejections carry provenance");
        assert_eq!(p.device, 3);
        assert!(p.breaker);
        assert!(Reject::Overloaded.is_overload());
        assert!(Reject::DeadlineInfeasible.is_overload());
        assert!(!Reject::QueueFull.is_overload());
        assert!(!br.is_overload());
    }

    #[test]
    fn reject_to_json_carries_kind_hint_and_provenance() {
        let br = Reject::BreakerOpen { device: 2, retry_after: Duration::from_millis(50) };
        let j = br.to_json();
        assert_eq!(j.get("error").and_then(Json::as_str), Some("breaker_open"));
        assert_eq!(j.get("status").and_then(Json::as_f64), Some(503.0));
        assert_eq!(j.get("device").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("breaker").and_then(Json::as_bool), Some(true));
        assert!((j.get("retry_after_ms").and_then(Json::as_f64).unwrap() - 50.0).abs() < 1e-9);
        let plain = Reject::QueueFull.to_json();
        assert_eq!(plain.get("error").and_then(Json::as_str), Some("queue_full"));
        assert!(plain.get("retry_after_ms").is_none());
        assert!(plain.get("device").is_none());
    }

    #[test]
    fn priority_orders_and_parses() {
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Batch);
        assert_eq!(Priority::High.rank(), 0);
        assert_eq!(Priority::Batch.rank(), 2);
        assert_eq!(Priority::parse("high"), Some(Priority::High));
        assert_eq!(Priority::parse("batch"), Some(Priority::Batch));
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::Normal.as_str(), "normal");
    }

    #[test]
    fn context_resolves_wire_deadline_not_config_default() {
        let arrived = Instant::now();
        let slo = Duration::from_millis(50);
        // No wire deadline: the SLO default applies.
        let d = RequestContext::new(0).resolve_deadline(arrived, slo);
        assert_eq!(d, arrived + slo);
        // Absolute wire deadline: honored verbatim, SLO ignored.
        let at = arrived + Duration::from_millis(7);
        let d = RequestContext::new(0).with_deadline(at).resolve_deadline(arrived, slo);
        assert_eq!(d, at);
        assert_ne!(d, arrived + slo);
        // Relative budget: anchored at arrival, SLO ignored.
        let d = RequestContext::new(0)
            .with_budget(Duration::from_millis(9))
            .resolve_deadline(arrived, slo);
        assert_eq!(d, arrived + Duration::from_millis(9));
    }

    #[test]
    fn context_materializes_into_request() {
        let arrived = Instant::now();
        let ctx = RequestContext::new(3)
            .with_budget(Duration::from_millis(20))
            .with_priority(Priority::High)
            .with_trace_id(77);
        let req = ctx.into_request(
            9,
            ShapeClass::batched_gemm(8, 8, 8),
            vec![],
            arrived,
            Duration::from_secs(1),
        );
        assert_eq!(req.id, 9);
        assert_eq!(req.tenant, 3);
        assert_eq!(req.deadline, arrived + Duration::from_millis(20));
        assert_eq!(req.priority, Priority::High);
        assert_eq!(req.trace_id, 77);
    }
}
