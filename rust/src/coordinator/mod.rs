//! The dynamic space-time scheduler — the paper's system contribution.
//!
//! Multi-tenant GPU inference coordination: per-tenant admission queues, a
//! shape-class dynamic batcher that merges same-shape GEMM problems from
//! *disjoint* model graphs into padded super-kernels (the paper's
//! `cublasSgemmBatched` insight), four scheduling policies (the §3
//! baselines plus the §4 space-time contribution), and an SLO monitor that
//! evicts stragglers to preserve predictability and isolation.
//!
//! * [`request`] — request/response types and the [`request::ShapeClass`]
//!   fusion key.
//! * [`tenant`] — registry of deployed models (same architecture,
//!   per-tenant weights — paper §2).
//! * [`queue`] — bounded admission front: per-tenant EDF heaps with depth
//!   caps plus a global cap that sheds with an explicit `Rejected` outcome.
//! * [`placement`] — which device of the pool each shape-class/tenant
//!   lands on (least-loaded with class affinity; eviction releases load,
//!   re-registration re-joins the class).
//! * [`costmodel`] — per-shape-class launch-latency predictor (analytic
//!   roofline seed + EWMA over measured durations) driving deadline-aware
//!   planning, admission, and the spatial-lane co-location interference
//!   term (per-lane-count stretch, EWMA over overlapped launches).
//! * [`batcher`] — shape-class bucketing + R-bucket round-up with padding
//!   accounting (MAGMA vbatch emulation).
//! * [`scheduler`] — Exclusive / TimeMux / SpaceMux / SpaceTime policies.
//! * [`superkernel`] — gather → one PJRT execution → scatter.
//! * [`monitor`] — per-tenant latency EWMA + straggler eviction, judged
//!   against same-device peers.
//! * [`driver`] — the sharded serve loop gluing it all together (one
//!   `RoundPlan` per device per round; multi-lane plans execute their
//!   lanes on concurrent worker threads).

pub mod batcher;
pub mod costmodel;
pub mod driver;
pub mod fusion_cache;
pub mod monitor;
pub mod placement;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod superkernel;
pub mod tenant;

pub use batcher::{BatcherStats, DynamicBatcher, Launch, PaddingPolicy};
pub use costmodel::{CostModel, SharedCostModel};
pub use driver::{Coordinator, RoundOutcome};
pub use fusion_cache::{FusionCache, FusionCacheStats, FusionKey, WeightSet};
pub use monitor::{Eviction, MonitorConfig, SloMonitor};
pub use placement::{place, DevicePlacer, Placement};
pub use queue::{QueueSet, TenantQueue};
pub use request::{InferenceRequest, InferenceResponse, Reject, RequestId, ShapeClass};
pub use scheduler::{
    launch_weight, make_scheduler, make_scheduler_deadline_aware, make_scheduler_spatial,
    RoundPlan, Scheduler,
};
pub use superkernel::{Flavor, LaunchResult, SuperKernelExec};
pub use tenant::{Health, ModelSpec, Tenant, TenantRegistry};
