//! The dynamic space-time scheduler — the paper's system contribution.
//!
//! Multi-tenant GPU inference coordination: per-tenant admission queues, a
//! shape-class dynamic batcher that merges same-shape GEMM problems from
//! *disjoint* model graphs into padded super-kernels (the paper's
//! `cublasSgemmBatched` insight), four scheduling policies (the §3
//! baselines plus the §4 space-time contribution), and an SLO monitor that
//! evicts stragglers to preserve predictability and isolation.
//!
//! * [`request`] — request/response types and the [`request::ShapeClass`]
//!   fusion key.
//! * [`tenant`] — registry of deployed models (same architecture,
//!   per-tenant weights — paper §2).
//! * [`queue`] — bounded admission front: per-tenant EDF heaps with depth
//!   caps plus a global cap that sheds with an explicit `Rejected` outcome.
//! * [`placement`] — which device of the pool each shape-class/tenant
//!   lands on (least-loaded with class affinity; eviction releases load,
//!   re-registration re-joins the class).
//! * [`costmodel`] — per-shape-class launch-latency predictor (analytic
//!   roofline seed + EWMA over measured durations) driving deadline-aware
//!   planning, admission, and the spatial-lane co-location interference
//!   term (per-lane-count stretch, EWMA over overlapped launches).
//! * [`controller`] — adaptive space-time controller: per-shard online
//!   (lanes, pipeline depth) reconfiguration from backlog, arrival-rate,
//!   cost-model and SLO-attainment signals, with dwell/hysteresis.
//! * [`batcher`] — shape-class bucketing + R-bucket round-up with padding
//!   accounting (MAGMA vbatch emulation).
//! * [`scheduler`] — Exclusive / TimeMux / SpaceMux / SpaceTime policies.
//! * [`superkernel`] — gather → one PJRT execution → scatter.
//! * [`monitor`] — per-tenant latency EWMA + straggler eviction, judged
//!   against same-device peers.
//! * [`protocol`] — the lane pipeline's synchronization protocol, generic
//!   over a [`protocol::SyncEnv`] so the same code runs under `std`
//!   primitives in production and under the deterministic model checker
//!   ([`crate::util::modelcheck`]) in tests.
//! * [`lanepool`] — persistent per-lane worker threads fed by SPSC work
//!   queues; round-tagged completions over one shared channel (the
//!   production [`protocol::StdEnv`] instantiation).
//! * [`driver`] — the sharded serve loop gluing it all together: a
//!   pipelined round loop (plan/marshal round N+1 while round N executes
//!   on the lane pool) over a recycled per-shard `RoundArena`.
//! * [`tuner`] — offline `(lanes, depth, EDF, controller)` autotuner
//!   (`stgpu tune`): budgeted grid + local-refinement search against
//!   gpusim ground truth, emitting a validated `[server]`/`[controller]`
//!   TOML fragment and a JSON leaderboard.
//! * [`journal`] — the append-only cluster decision journal:
//!   length-prefixed, checksummed JSON records under a running FNV-1a-64
//!   digest; `stgpu replay` re-executes a journal and diffs digests.
//! * [`cluster`] — the cluster tier: a sequencer issuing round tickets, N
//!   in-process node workers (each a full scheduler/controller stack),
//!   and a committer applying results strictly in ticket order into the
//!   journal, with tenant migration on hotspot and node failure/rejoin.

pub mod batcher;
pub mod cluster;
pub mod controller;
pub mod costmodel;
pub mod driver;
pub mod fusion_cache;
pub mod journal;
pub mod lanepool;
pub mod monitor;
pub mod placement;
pub mod protocol;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod superkernel;
pub mod tenant;
pub mod tuner;

pub use batcher::{BatcherStats, DynamicBatcher, Launch, PaddingPolicy};
pub use cluster::{replay_journal, run_cluster, ClusterOpts, ClusterReport, ReplayOutcome};
pub use controller::{
    AdaptiveController, ControlSignals, ControllerParams, Decision, SignalTracker,
};
pub use costmodel::{CostModel, SharedCostModel};
pub use driver::{Coordinator, ControlPlan, RoundArena, RoundOutcome};
pub use fusion_cache::{FusionCache, FusionCacheStats, FusionKey, WeightSet};
pub use lanepool::{Completion, LanePool, LaunchExecutor, PjrtExecutor, WorkItem};
pub use journal::{fnv1a32, fnv1a64, Journal};
pub use monitor::{Eviction, MonitorConfig, SloMonitor};
pub use placement::{place, ClusterPlacer, DevicePlacer, Placement};
pub use protocol::{
    ItemRunner, LaneProtocol, LaneTagged, ProtoJoin, ProtoPayload, ProtoReceiver, ProtoSender,
    StdEnv, SyncEnv,
};
pub use queue::{QueueSet, TenantQueue};
pub use request::{
    DeadlineSpec, InferenceRequest, InferenceResponse, Priority, Reject, RejectKind,
    RejectProvenance, RequestContext, RequestId, ShapeClass,
};
pub use scheduler::{
    launch_weight, make_scheduler, make_scheduler_deadline_aware, make_scheduler_spatial,
    RoundPlan, Scheduler,
};
pub use superkernel::{Flavor, LaunchResult, SuperKernelExec};
pub use tenant::{Health, ModelSpec, Tenant, TenantRegistry};
pub use tuner::{tune, TuneOutcome, TunePoint, TuneReport};
