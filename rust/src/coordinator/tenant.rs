//! Tenant registry: deployed model instances sharing one device.
//!
//! Paper §2 application model: all tenants on a GPU share the same
//! architecture but have *different weights*. The registry owns each
//! tenant's weights (seeded deterministically), SLO, and health state the
//! straggler monitor mutates.

use crate::config::TenantConfig;
use crate::coordinator::request::ShapeClass;
use crate::runtime::HostTensor;
use crate::util::prng::Rng;

/// Health as tracked by the SLO monitor (paper §4: monitor per-kernel
/// latency, evict degraded workers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    /// Exceeded the straggler threshold in the last window(s).
    Degraded { strikes: u32 },
    Evicted,
}

/// Architecture deployed by a tenant, parsed from the config `model` string.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSpec {
    /// Raw SGEMM problems (`sgemm:MxNxK`) — the paper's §4.1 benchmark unit.
    Sgemm { m: usize, n: usize, k: usize },
    /// Two-layer MLP block (`mlp`) — the end-to-end serving unit.
    Mlp { m: usize, hidden: usize, k: usize, n_out: usize },
    /// Single dense layer with fused bias+ReLU epilogue (`fused_linear`) —
    /// the one-kernel-per-request unit (TensorRT-style folded inference).
    FusedLinear { m: usize, k: usize, n: usize },
    /// RNN cell (`rnn_cell`) — the paper's Table 1 matvec workload.
    RnnCell { hidden: usize },
}

impl ModelSpec {
    /// Parse the config string: `sgemm:256x128x1152`, `mlp`, `rnn_cell`.
    pub fn parse(s: &str) -> Result<Self, String> {
        if let Some(dims) = s.strip_prefix("sgemm:") {
            let parts: Vec<usize> = dims
                .split('x')
                .map(|p| p.parse().map_err(|_| format!("bad sgemm dims {dims:?}")))
                .collect::<Result<_, _>>()?;
            if parts.len() != 3 || parts.contains(&0) {
                return Err(format!("sgemm spec needs MxNxK, got {dims:?}"));
            }
            return Ok(ModelSpec::Sgemm { m: parts[0], n: parts[1], k: parts[2] });
        }
        match s {
            "mlp" | "mlp_block" => Ok(ModelSpec::Mlp {
                m: 8,
                hidden: 512,
                k: 256,
                n_out: 256,
            }),
            "fused_linear" | "linear" => {
                Ok(ModelSpec::FusedLinear { m: 8, k: 512, n: 256 })
            }
            "rnn_cell" | "rnn" => Ok(ModelSpec::RnnCell { hidden: 512 }),
            other => Err(format!(
                "unknown model {other:?} (expected sgemm:MxNxK | mlp | fused_linear | rnn_cell)"
            )),
        }
    }

    pub fn shape_class(&self) -> ShapeClass {
        match *self {
            ModelSpec::Sgemm { m, n, k } => ShapeClass::batched_gemm(m, n, k),
            ModelSpec::Mlp { m, hidden, k, n_out } => {
                ShapeClass::mlp_block(m, hidden, k, n_out)
            }
            ModelSpec::FusedLinear { m, k, n } => ShapeClass::fused_linear(m, n, k),
            ModelSpec::RnnCell { hidden } => ShapeClass::rnn_cell(hidden),
        }
    }

    /// Per-request payload tensor shapes (what clients must send).
    pub fn payload_shapes(&self) -> Vec<Vec<usize>> {
        match *self {
            ModelSpec::Sgemm { m, n, k } => vec![vec![m, k], vec![k, n]],
            ModelSpec::Mlp { m, k, .. } => vec![vec![m, k]],
            ModelSpec::FusedLinear { m, k, .. } => vec![vec![m, k]],
            ModelSpec::RnnCell { hidden } => vec![vec![hidden, 1], vec![hidden, 1]],
        }
    }

    /// Weight tensor shapes owned by the tenant (empty for raw SGEMM).
    pub fn weight_shapes(&self) -> Vec<Vec<usize>> {
        match *self {
            ModelSpec::Sgemm { .. } => vec![],
            ModelSpec::Mlp { hidden, k, n_out, .. } => vec![
                vec![k, hidden],
                vec![1, hidden],
                vec![hidden, n_out],
            ],
            ModelSpec::FusedLinear { k, n, .. } => {
                vec![vec![k, n], vec![1, n]]
            }
            ModelSpec::RnnCell { hidden } => {
                vec![vec![hidden, hidden], vec![hidden, hidden]]
            }
        }
    }
}

/// One deployed tenant.
#[derive(Debug)]
pub struct Tenant {
    pub id: usize,
    pub name: String,
    pub spec: ModelSpec,
    pub slo_ms: f64,
    /// Deterministic per-tenant weights (paper §2: same architecture,
    /// different weights).
    pub weights: Vec<HostTensor>,
    pub health: Health,
}

impl Tenant {
    pub fn is_servable(&self) -> bool {
        self.health != Health::Evicted
    }
}

/// The registry. Index == tenant id.
#[derive(Debug, Default)]
pub struct TenantRegistry {
    tenants: Vec<Tenant>,
}

impl TenantRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from config entries.
    pub fn from_configs(cfgs: &[TenantConfig]) -> Result<Self, String> {
        let mut reg = Self::new();
        for c in cfgs {
            reg.register(&c.name, &c.model, c.slo_ms, c.weight_seed)?;
        }
        Ok(reg)
    }

    /// Register a tenant; returns its id.
    pub fn register(
        &mut self,
        name: &str,
        model: &str,
        slo_ms: f64,
        weight_seed: u64,
    ) -> Result<usize, String> {
        let spec = ModelSpec::parse(model)?;
        let mut rng = Rng::new(weight_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xD1F3);
        let weights = spec
            .weight_shapes()
            .iter()
            .map(|s| HostTensor::random(s, &mut rng))
            .collect();
        let id = self.tenants.len();
        self.tenants.push(Tenant {
            id,
            name: name.to_string(),
            spec,
            slo_ms,
            weights,
            health: Health::Healthy,
        });
        Ok(id)
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    pub fn get(&self, id: usize) -> Option<&Tenant> {
        self.tenants.get(id)
    }

    pub fn get_mut(&mut self, id: usize) -> Option<&mut Tenant> {
        self.tenants.get_mut(id)
    }

    pub fn iter(&self) -> impl Iterator<Item = &Tenant> {
        self.tenants.iter()
    }

    pub fn servable(&self) -> impl Iterator<Item = &Tenant> {
        self.tenants.iter().filter(|t| t.is_servable())
    }

    pub fn evict(&mut self, id: usize) {
        if let Some(t) = self.tenants.get_mut(id) {
            t.health = Health::Evicted;
        }
    }

    pub fn evicted_count(&self) -> usize {
        self.tenants
            .iter()
            .filter(|t| t.health == Health::Evicted)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_model_specs() {
        assert_eq!(
            ModelSpec::parse("sgemm:256x128x1152").unwrap(),
            ModelSpec::Sgemm { m: 256, n: 128, k: 1152 }
        );
        assert!(matches!(ModelSpec::parse("mlp").unwrap(), ModelSpec::Mlp { .. }));
        assert!(matches!(
            ModelSpec::parse("rnn_cell").unwrap(),
            ModelSpec::RnnCell { hidden: 512 }
        ));
        assert!(ModelSpec::parse("sgemm:1x2").is_err());
        assert!(ModelSpec::parse("sgemm:0x1x1").is_err());
        assert!(ModelSpec::parse("bert").is_err());
    }

    #[test]
    fn weights_differ_by_seed_not_by_call() {
        let mut reg = TenantRegistry::new();
        let a = reg.register("a", "mlp", 100.0, 1).unwrap();
        let b = reg.register("b", "mlp", 100.0, 2).unwrap();
        let c = reg.register("c", "mlp", 100.0, 1).unwrap();
        let (wa, wb, wc) = (
            &reg.get(a).unwrap().weights,
            &reg.get(b).unwrap().weights,
            &reg.get(c).unwrap().weights,
        );
        assert_eq!(wa.len(), 3);
        assert_ne!(wa[0], wb[0], "different seeds -> different weights");
        assert_eq!(wa[0], wc[0], "same seed -> same weights");
    }

    #[test]
    fn sgemm_tenants_have_no_weights() {
        let mut reg = TenantRegistry::new();
        let id = reg.register("g", "sgemm:64x64x64", 50.0, 0).unwrap();
        assert!(reg.get(id).unwrap().weights.is_empty());
        assert_eq!(
            reg.get(id).unwrap().spec.payload_shapes(),
            vec![vec![64, 64], vec![64, 64]]
        );
    }

    #[test]
    fn eviction_flips_servability() {
        let mut reg = TenantRegistry::new();
        let id = reg.register("x", "mlp", 100.0, 0).unwrap();
        assert!(reg.get(id).unwrap().is_servable());
        reg.evict(id);
        assert!(!reg.get(id).unwrap().is_servable());
        assert_eq!(reg.evicted_count(), 1);
        assert_eq!(reg.servable().count(), 0);
    }

    #[test]
    fn from_configs_roundtrip() {
        let cfgs = vec![
            TenantConfig {
                name: "t0".into(),
                model: "sgemm:256x256x256".into(),
                batch: 1,
                slo_ms: 25.0,
                weight_seed: 7,
            },
            TenantConfig {
                name: "t1".into(),
                model: "mlp".into(),
                batch: 1,
                slo_ms: 50.0,
                weight_seed: 8,
            },
        ];
        let reg = TenantRegistry::from_configs(&cfgs).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.get(0).unwrap().slo_ms, 25.0);
        assert_eq!(reg.get(1).unwrap().name, "t1");
    }
}
