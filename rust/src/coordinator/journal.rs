//! The append-only cluster decision journal.
//!
//! Every round the cluster committer commits (strictly in sequencer ticket
//! order — see [`crate::coordinator::cluster`]) appends ONE record here:
//! the node's plan digest, its lane map, controller reconfiguration
//! counters, and any placement decision (tenant migration, node down/up)
//! the committer made at that round boundary. The journal is the cluster
//! tier's source of truth: `stgpu replay <journal>` re-executes the header
//! configuration through the serial path and asserts a bitwise-identical
//! digest, which is what makes parallel planning testable against serial
//! planning (the PR 4/5 `depth=1` / `adaptive=false` equivalence trick,
//! promoted to an architectural invariant).
//!
//! ## On-disk format
//!
//! A flat sequence of length-prefixed, checksummed JSON records:
//!
//! ```text
//! [len: u32 LE] [body: `len` bytes of compact JSON] [fnv1a32(body): u32 LE]
//! ```
//!
//! * The JSON body is emitted by [`crate::util::json::Json`], whose object
//!   maps are `BTreeMap`s — key order (and therefore the byte stream) is a
//!   pure function of the record's content.
//! * The running **digest** is FNV-1a-64 over every framed byte in append
//!   order. Two journals are bitwise identical iff their digests and
//!   lengths match; the digest alone is what replay compares.
//! * Record kinds (the `"kind"` field): `header` (the full run
//!   configuration — a journal is self-contained for replay), `round` (one
//!   per committed ticket), `migrate`, `node_down`, `node_up`, `summary`.
//!
//! Determinism contract: the append/decode paths are annotated
//! `// lint: pure` — no clock, no RNG, no `HashMap` iteration (the xtask
//! lint's `pure-clock` and `pure-map-iter` rules enforce both). Records
//! must only ever contain values that are themselves deterministic
//! functions of the run configuration: relative times, counts, digests —
//! never wall-clock timestamps.

use std::path::Path;

use crate::util::json::Json;

/// FNV-1a 64-bit offset basis (the running-digest seed).
pub const FNV64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV64_PRIME: u64 = 0x0000_0100_0000_01b3;
const FNV32_OFFSET: u32 = 0x811c_9dc5;
const FNV32_PRIME: u32 = 0x0100_0193;

/// Fold `bytes` into a running FNV-1a-64 hash (seed with
/// [`FNV64_OFFSET`]). Used for the journal digest and for plan digests.
// lint: pure
pub fn fnv1a64(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV64_PRIME);
    }
    h
}

/// FNV-1a-32 of `bytes` — the per-record checksum.
// lint: pure
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h = FNV32_OFFSET;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(FNV32_PRIME);
    }
    h
}

/// An append-only decision journal: parsed records plus the exact framed
/// byte stream and its running digest.
pub struct Journal {
    records: Vec<Json>,
    bytes: Vec<u8>,
    digest: u64,
}

impl Default for Journal {
    fn default() -> Self {
        Self::new()
    }
}

impl Journal {
    pub fn new() -> Self {
        Self { records: Vec::new(), bytes: Vec::new(), digest: FNV64_OFFSET }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[Json] {
        &self.records
    }

    /// The framed byte stream exactly as it would be written to disk.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Running FNV-1a-64 over every framed byte appended so far.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    pub fn digest_hex(&self) -> String {
        format!("{:016x}", self.digest)
    }

    /// Append one record: frame it (length prefix + FNV-1a-32 checksum)
    /// and fold the frame into the running digest.
    // lint: pure
    pub fn append(&mut self, record: Json) {
        let body = record.to_string().into_bytes();
        let len = body.len() as u32;
        let sum = fnv1a32(&body);
        let at = self.bytes.len();
        self.bytes.extend_from_slice(&len.to_le_bytes());
        self.bytes.extend_from_slice(&body);
        self.bytes.extend_from_slice(&sum.to_le_bytes());
        self.digest = fnv1a64(self.digest, &self.bytes[at..]);
        self.records.push(record);
    }

    /// Decode a framed byte stream, verifying every record's length prefix
    /// and checksum. The returned journal preserves the input bytes
    /// verbatim (records are *parsed from*, never re-encoded into, the
    /// stream — float formatting round-trips are not assumed).
    // lint: pure
    pub fn decode(bytes: &[u8]) -> Result<Journal, String> {
        let mut records = Vec::new();
        let mut i = 0usize;
        while i < bytes.len() {
            let rec = records.len();
            if i + 4 > bytes.len() {
                return Err(format!("record {rec}: truncated length prefix at byte {i}"));
            }
            let len =
                u32::from_le_bytes([bytes[i], bytes[i + 1], bytes[i + 2], bytes[i + 3]]) as usize;
            i += 4;
            if i + len + 4 > bytes.len() {
                return Err(format!("record {rec}: body/checksum truncated (len {len})"));
            }
            let body = &bytes[i..i + len];
            i += len;
            let want =
                u32::from_le_bytes([bytes[i], bytes[i + 1], bytes[i + 2], bytes[i + 3]]);
            i += 4;
            let got = fnv1a32(body);
            if got != want {
                return Err(format!(
                    "record {rec}: checksum mismatch (stored {want:08x}, computed {got:08x})"
                ));
            }
            let text = std::str::from_utf8(body)
                .map_err(|e| format!("record {rec}: body is not UTF-8: {e}"))?;
            let json = Json::parse(text).map_err(|e| format!("record {rec}: {e}"))?;
            records.push(json);
        }
        let digest = fnv1a64(FNV64_OFFSET, bytes);
        Ok(Journal { records, bytes: bytes.to_vec(), digest })
    }

    /// Write the framed stream to `path`, creating parent directories.
    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, &self.bytes)
    }

    /// Read and verify a journal file.
    pub fn read_from(path: &Path) -> Result<Journal, String> {
        let bytes =
            std::fs::read(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a32(b""), 0x811c_9dc5);
        assert_eq!(fnv1a32(b"a"), 0xe40c_292c);
        assert_eq!(fnv1a64(FNV64_OFFSET, b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(FNV64_OFFSET, b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    fn sample() -> Journal {
        let mut j = Journal::new();
        j.append(Json::obj(vec![
            ("kind", Json::str("header")),
            ("nodes", Json::num(4)),
            ("round_s", Json::num(0.0025)),
        ]));
        j.append(Json::obj(vec![
            ("kind", Json::str("round")),
            ("ticket", Json::num(0)),
            ("plan", Json::str("00ff00ff00ff00ff")),
        ]));
        j.append(Json::obj(vec![
            ("kind", Json::str("summary")),
            ("completed", Json::num(128)),
        ]));
        j
    }

    #[test]
    fn round_trips_through_decode_bit_for_bit() {
        let j = sample();
        let back = Journal::decode(j.bytes()).expect("decode");
        assert_eq!(back.len(), 3);
        assert_eq!(back.bytes(), j.bytes());
        assert_eq!(back.digest(), j.digest());
        assert_eq!(back.digest_hex(), j.digest_hex());
        for (a, b) in back.records().iter().zip(j.records()) {
            assert_eq!(a.to_string(), b.to_string());
        }
    }

    #[test]
    fn digest_is_a_pure_function_of_the_records() {
        let (a, b) = (sample(), sample());
        assert_eq!(a.digest_hex(), b.digest_hex());
        let mut c = Journal::new();
        c.append(Json::obj(vec![("kind", Json::str("header"))]));
        assert_ne!(a.digest_hex(), c.digest_hex());
    }

    #[test]
    fn corrupted_body_is_rejected_by_the_checksum() {
        let j = sample();
        let mut bytes = j.bytes().to_vec();
        // Flip a byte inside the first record's JSON body (past the
        // 4-byte length prefix).
        bytes[6] ^= 0x20;
        let err = Journal::decode(&bytes).expect_err("corruption must be caught");
        assert!(err.contains("checksum"), "got: {err}");
    }

    #[test]
    fn truncated_stream_is_rejected() {
        let j = sample();
        let bytes = j.bytes();
        let err = Journal::decode(&bytes[..bytes.len() - 3]).expect_err("truncation");
        assert!(err.contains("truncated"), "got: {err}");
        let err = Journal::decode(&bytes[..2]).expect_err("short prefix");
        assert!(err.contains("truncated"), "got: {err}");
    }

    #[test]
    fn write_and_read_round_trip_on_disk() {
        let j = sample();
        let dir = std::env::temp_dir().join("stgpu-journal-test");
        let path = dir.join("sub").join("j.bin");
        j.write_to(&path).expect("write");
        let back = Journal::read_from(&path).expect("read");
        assert_eq!(back.digest_hex(), j.digest_hex());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
