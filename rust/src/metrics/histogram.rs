//! Log-bucketed latency histogram (HdrHistogram-style, base-2 sub-bucketed).
//!
//! Values are recorded in nanoseconds as `u64`. Buckets are exponential with
//! `SUB_BUCKETS` linear sub-buckets per octave, giving a bounded relative
//! error of `1/SUB_BUCKETS` — sufficient for p50/p99 reporting while keeping
//! recording allocation-free and O(1), which the coordinator hot path needs.

/// Linear sub-buckets per power-of-two octave. 32 → ≤3.2 % relative error.
const SUB_BUCKETS: u64 = 32;
/// Octave rows allocated (the first row is the exact linear region
/// [0, SUB_BUCKETS)). Values up to `(2*SUB_BUCKETS - 1) << (OCTAVES - 2)`
/// (≈ 2^44 ns ≈ 4.8 h) bucket with full resolution; anything beyond
/// clamps into the top bucket — far above any latency this system records.
/// The round-trip contract (`bucket_value(bucket_index(v)) <= v`, relative
/// error < 1/SUB_BUCKETS below the clamp) is property-tested below.
const OCTAVES: usize = 40;
const NBUCKETS: usize = OCTAVES * SUB_BUCKETS as usize;

#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; NBUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn bucket_index(value: u64) -> usize {
        if value < SUB_BUCKETS {
            return value as usize;
        }
        // Octave = position of the highest set bit above the sub-bucket base.
        let octave = 63 - value.leading_zeros() as u64; // >= 5
        let base_octave = SUB_BUCKETS.trailing_zeros() as u64; // 5 for 32
        let oct = octave - base_octave; // >= 0
        let shift = oct; // divide into SUB_BUCKETS linear slots
        let sub = (value >> shift) - SUB_BUCKETS; // in [0, SUB_BUCKETS)
        let idx = ((oct + 1) * SUB_BUCKETS + sub) as usize;
        idx.min(NBUCKETS - 1)
    }

    /// Lower edge of a bucket (inverse of `bucket_index`, approximate).
    fn bucket_value(idx: usize) -> u64 {
        let idx = idx as u64;
        if idx < SUB_BUCKETS {
            return idx;
        }
        let oct = idx / SUB_BUCKETS - 1;
        let sub = idx % SUB_BUCKETS;
        (SUB_BUCKETS + sub) << oct
    }

    #[inline]
    pub fn record(&mut self, value_ns: u64) {
        self.counts[Self::bucket_index(value_ns)] += 1;
        self.total += 1;
        self.sum += value_ns as u128;
        self.min = self.min.min(value_ns);
        self.max = self.max.max(value_ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max
    }

    /// Approximate percentile (p in [0,100]) in nanoseconds.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // Clamp to observed extrema so tails stay exact-ish.
                return Self::bucket_value(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.total)
            .field("mean_ns", &self.mean_ns())
            .field("p50_ns", &self.percentile_ns(50.0))
            .field("p99_ns", &self.percentile_ns(99.0))
            .field("max_ns", &self.max_ns())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile_ns(50.0), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn exact_for_small_values() {
        let mut h = Histogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 31);
        assert_eq!(h.count(), 32);
    }

    #[test]
    fn bucket_index_monotone() {
        let mut last = 0usize;
        for v in (0..1_000_000u64).step_by(997) {
            let idx = Histogram::bucket_index(v);
            assert!(idx >= last, "bucket index must be monotone in value");
            last = idx;
        }
    }

    #[test]
    fn bucket_value_inverts_within_error() {
        for v in [1u64, 31, 32, 33, 100, 1_000, 123_456, 10_000_000, 1 << 35] {
            let idx = Histogram::bucket_index(v);
            let lo = Histogram::bucket_value(idx);
            let hi = Histogram::bucket_value(idx + 1);
            assert!(lo <= v && v < hi.max(lo + 1), "v={v} lo={lo} hi={hi}");
        }
    }

    #[test]
    fn prop_bucket_round_trip_is_lower_edge_with_bounded_error() {
        // The octave-boundary contract: for every value below the clamp,
        // bucket_value(bucket_index(v)) is a LOWER edge (never exceeds v),
        // within 1/SUB_BUCKETS relative error, and the edge maps back to
        // the same bucket (no off-by-one drift at 2^k boundaries).
        let check = |v: u64| {
            let idx = Histogram::bucket_index(v);
            let lo = Histogram::bucket_value(idx);
            assert!(lo <= v, "v={v} idx={idx} lo={lo}: edge above the value");
            let err = (v - lo) as f64 / v.max(1) as f64;
            assert!(
                err < 1.0 / SUB_BUCKETS as f64 + 1e-12,
                "v={v} lo={lo} rel err {err} exceeds 1/{SUB_BUCKETS}"
            );
            assert_eq!(
                Histogram::bucket_index(lo),
                idx,
                "v={v}: lower edge {lo} drifts to another bucket"
            );
        };
        // Octave boundaries across the whole documented range [0, 2^40):
        // every power of two, one below, one above.
        check(0);
        for exp in 0..40u32 {
            let p = 1u64 << exp;
            check(p - 1);
            check(p);
            check(p + 1);
        }
        // Randomized sweep over the same range.
        let mut rng = Rng::new(0xB0C4);
        for _ in 0..20_000 {
            check(rng.gen_range(1u64 << 40));
        }
        // Above the clamp the lower-edge property still holds (relative
        // error is unbounded there by design — it is out of range).
        for v in [u64::MAX, 1 << 50, (63u64 << 38) + 1] {
            let lo = Histogram::bucket_value(Histogram::bucket_index(v));
            assert!(lo <= v);
        }
    }

    #[test]
    fn percentile_relative_error_bounded() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(11);
        let mut values: Vec<u64> = (0..50_000)
            .map(|_| rng.gen_range_inclusive(100, 50_000_000))
            .collect();
        for &v in &values {
            h.record(v);
        }
        values.sort_unstable();
        for &p in &[50.0, 90.0, 99.0] {
            let exact = values[((p / 100.0) * (values.len() - 1) as f64) as usize] as f64;
            let approx = h.percentile_ns(p) as f64;
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.05, "p{p}: approx {approx} exact {exact} rel {rel}");
        }
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut both = Histogram::new();
        let mut rng = Rng::new(12);
        for i in 0..10_000 {
            let v = rng.gen_range_inclusive(1, 1_000_000);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.percentile_ns(50.0), both.percentile_ns(50.0));
        assert_eq!(a.max_ns(), both.max_ns());
    }

    #[test]
    fn reset_clears() {
        let mut h = Histogram::new();
        h.record(123);
        h.reset();
        assert!(h.is_empty());
        assert_eq!(h.max_ns(), 0);
    }
}
