//! Metrics: latency histograms, throughput counters and a registry that the
//! server exposes and the bench harness snapshots.

pub mod histogram;
pub mod registry;

pub use histogram::Histogram;
pub use registry::{DeviceSnapshot, MetricsRegistry, Snapshot, TenantMetrics, STATUS_SCHEMA_VERSION};
