//! Per-tenant and global metric registry.
//!
//! Shared between the server's worker threads (which record) and the
//! frontend/bench harness (which snapshot). Recording takes a mutex per
//! tenant; the hot path amortizes this by recording per *super-kernel batch*
//! rather than per request where possible.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::histogram::Histogram;
use crate::util::json::Json;

/// Version of the status JSON schema emitted by [`Snapshot::to_json`]
/// (and the `/status` endpoint that serves it). Consumers should accept
/// unknown keys within a version; the version bumps only when existing
/// keys change meaning or move. Version history is documented in the
/// README's "Status endpoint" section.
pub const STATUS_SCHEMA_VERSION: u64 = 2;

/// Metrics owned by one tenant (one deployed model replica).
#[derive(Debug, Default)]
pub struct TenantMetrics {
    inner: Mutex<TenantInner>,
    /// Requests completed (atomic so readers never block the hot path).
    pub completed: AtomicU64,
    /// Requests rejected at admission (queue full).
    pub rejected: AtomicU64,
    /// Times this tenant was evicted for straggling.
    pub evictions: AtomicU64,
    /// Requests completed before their SLO deadline.
    pub deadline_hits: AtomicU64,
    /// Requests completed after their SLO deadline.
    pub deadline_misses: AtomicU64,
}

#[derive(Debug, Default)]
struct TenantInner {
    /// End-to-end request latency (queue + service), ns.
    latency: Histogram,
    /// Service time only (kernel execution), ns.
    service: Histogram,
    /// FLOPs completed on behalf of this tenant.
    flops: f64,
}

impl TenantMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_completion(&self, latency_ns: u64, service_ns: u64, flops: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.latency.record(latency_ns);
        inner.service.record(service_ns);
        inner.flops += flops;
        drop(inner);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejection(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Record whether a completed request met its SLO deadline.
    pub fn record_deadline(&self, met: bool) {
        if met {
            self.deadline_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> TenantSnapshot {
        let inner = self.inner.lock().unwrap();
        TenantSnapshot {
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            deadline_hits: self.deadline_hits.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            latency_p50_ns: inner.latency.percentile_ns(50.0),
            latency_p99_ns: inner.latency.percentile_ns(99.0),
            latency_mean_ns: inner.latency.mean_ns(),
            latency_max_ns: inner.latency.max_ns(),
            service_p50_ns: inner.service.percentile_ns(50.0),
            service_mean_ns: inner.service.mean_ns(),
            flops: inner.flops,
        }
    }
}

/// Immutable view of one tenant's metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub evictions: u64,
    pub deadline_hits: u64,
    pub deadline_misses: u64,
    pub latency_p50_ns: u64,
    pub latency_p99_ns: u64,
    pub latency_mean_ns: f64,
    pub latency_max_ns: u64,
    pub service_p50_ns: u64,
    pub service_mean_ns: f64,
    pub flops: f64,
}

impl TenantSnapshot {
    /// SLO-attainment ratio (deadline hits / completions with a verdict);
    /// None before any completion.
    pub fn slo_attainment(&self) -> Option<f64> {
        let total = self.deadline_hits + self.deadline_misses;
        if total == 0 {
            None
        } else {
            Some(self.deadline_hits as f64 / total as f64)
        }
    }
}

/// Per-device counters in a snapshot (sharded coordinator; one entry per
/// pool device, filled by `Coordinator::snapshot`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceSnapshot {
    pub device: usize,
    /// Tenants placed on this device.
    pub tenants: u64,
    /// Requests currently queued on this shard.
    pub pending: u64,
    pub launches: u64,
    pub superkernel_launches: u64,
    /// Requests drained into launches over the lifetime.
    pub drained: u64,
    /// Requests shed at admission (global cap) attributed to this shard.
    pub shed: u64,
    /// Fused launches the deadline-aware planner split on this shard.
    pub deadline_splits: u64,
    /// EWMA relative error of the shard's launch-latency predictor
    /// (0.0 when EDF planning is off or nothing has been observed).
    pub cost_calibration_error: f64,
    /// Launches executed per spatial lane (index == lane id; one entry
    /// when the shard runs serial rounds).
    pub lane_launches: Vec<u64>,
    /// Busy seconds (marshal + execute) accumulated per spatial lane —
    /// `lane_busy_s[i] / wall` is lane i's utilization.
    pub lane_busy_s: Vec<f64>,
    /// Launches each lane stole from a sibling's queue (thief-side; index
    /// == thief lane id). All zeros with `[server] steal = false`.
    pub lane_steals: Vec<u64>,
    /// Failed launches retried once on another lane via the steal path.
    pub launch_retries: u64,
    /// Interference-model calibration: (concurrent lane count, EWMA
    /// relative prediction error) for every lane count with at least one
    /// overlapped observation.
    pub lane_calibration: Vec<(usize, f64)>,
    /// Whether the adaptive space-time controller drives this shard.
    pub ctrl_adaptive: bool,
    /// Resident spatial lanes right now (the controller's current choice;
    /// the static `lanes` knob when the controller is off).
    pub ctrl_lanes: u64,
    /// Effective pipeline depth right now.
    pub ctrl_depth: u64,
    /// Times the controller changed (lanes, depth) over the lifetime.
    pub ctrl_reconfigs: u64,
    /// Decision points the controller evaluated (dwell boundaries with
    /// usable signals).
    pub ctrl_evals: u64,
    /// Predicted utility (req/s) of the chosen decision at the last
    /// evaluation.
    pub ctrl_utility: f64,
    /// Best predicted utility per candidate lane count at the last
    /// evaluation, ascending lane count (empty before the first decision
    /// point, or with the controller off).
    pub ctrl_utilities: Vec<(usize, f64)>,
    /// Fusion-cache (device-resident weight set) lookups that hit.
    pub cache_hits: u64,
    /// Fusion-cache lookups that missed (a host gather + upload).
    pub cache_misses: u64,
    /// Fusion-cache entries evicted (LRU capacity + tenant invalidation).
    pub cache_evictions: u64,
    /// Weight sets currently resident on this device.
    pub cache_resident: u64,
    /// FLOPs executed on this device.
    pub flops: f64,
}

impl DeviceSnapshot {
    /// Per-lane utilization over `wall` seconds (empty when no lane has
    /// executed anything).
    pub fn lane_utilization(&self, wall: f64) -> Vec<f64> {
        if wall <= 0.0 {
            return vec![0.0; self.lane_busy_s.len()];
        }
        self.lane_busy_s.iter().map(|&b| b / wall).collect()
    }
}

/// Whole-system snapshot: per-tenant plus aggregates.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub tenants: BTreeMap<String, TenantSnapshot>,
    pub wall_seconds: f64,
    /// Super-kernel launches issued by the space-time scheduler.
    pub superkernel_launches: u64,
    /// Total kernel launches (any scheduler).
    pub kernel_launches: u64,
    /// Super-kernel cache hits (compiled-executable reuse).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Per-device section (empty when snapshotted outside a coordinator).
    pub devices: Vec<DeviceSnapshot>,
}

impl Snapshot {
    pub fn total_completed(&self) -> u64 {
        self.tenants.values().map(|t| t.completed).sum()
    }

    pub fn total_flops(&self) -> f64 {
        self.tenants.values().map(|t| t.flops).sum()
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.total_completed() as f64 / self.wall_seconds
        }
    }

    pub fn throughput_flops(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.total_flops() / self.wall_seconds
        }
    }

    /// Fastest-vs-slowest mean-latency gap across tenants — the paper's
    /// Figure 4 predictability metric. Returns e.g. 0.25 for a 25 % gap.
    pub fn straggler_gap(&self) -> f64 {
        let means: Vec<f64> = self
            .tenants
            .values()
            .filter(|t| t.completed > 0)
            .map(|t| t.latency_mean_ns)
            .collect();
        if means.len() < 2 {
            return 0.0;
        }
        let fastest = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let slowest = means.iter().cloned().fold(0.0, f64::max);
        if fastest <= 0.0 {
            0.0
        } else {
            slowest / fastest - 1.0
        }
    }

    pub fn to_json(&self) -> Json {
        let tenants = Json::Obj(
            self.tenants
                .iter()
                .map(|(name, t)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("completed", Json::num(t.completed as f64)),
                            ("rejected", Json::num(t.rejected as f64)),
                            ("evictions", Json::num(t.evictions as f64)),
                            ("deadline_hits", Json::num(t.deadline_hits as f64)),
                            ("deadline_misses", Json::num(t.deadline_misses as f64)),
                            (
                                "slo_attainment",
                                t.slo_attainment().map_or(Json::Null, |a| Json::num(a)),
                            ),
                            ("latency_p50_ns", Json::num(t.latency_p50_ns as f64)),
                            ("latency_p99_ns", Json::num(t.latency_p99_ns as f64)),
                            ("latency_mean_ns", Json::num(t.latency_mean_ns)),
                            ("flops", Json::num(t.flops)),
                        ]),
                    )
                })
                .collect(),
        );
        let devices = Json::Arr(
            self.devices
                .iter()
                .map(|d| {
                    Json::obj(vec![
                        ("device", Json::num(d.device as f64)),
                        ("tenants", Json::num(d.tenants as f64)),
                        ("pending", Json::num(d.pending as f64)),
                        ("launches", Json::num(d.launches as f64)),
                        (
                            "superkernel_launches",
                            Json::num(d.superkernel_launches as f64),
                        ),
                        ("drained", Json::num(d.drained as f64)),
                        ("shed", Json::num(d.shed as f64)),
                        ("deadline_splits", Json::num(d.deadline_splits as f64)),
                        (
                            "cost_calibration_error",
                            Json::num(d.cost_calibration_error),
                        ),
                        (
                            "lane_launches",
                            Json::Arr(
                                d.lane_launches
                                    .iter()
                                    .map(|&l| Json::num(l as f64))
                                    .collect(),
                            ),
                        ),
                        (
                            "lane_busy_s",
                            Json::Arr(
                                d.lane_busy_s.iter().map(|&b| Json::num(b)).collect(),
                            ),
                        ),
                        (
                            "lane_steals",
                            Json::Arr(
                                d.lane_steals
                                    .iter()
                                    .map(|&s| Json::num(s as f64))
                                    .collect(),
                            ),
                        ),
                        ("launch_retries", Json::num(d.launch_retries as f64)),
                        (
                            "lane_calibration",
                            Json::Obj(
                                d.lane_calibration
                                    .iter()
                                    .map(|&(l, e)| (l.to_string(), Json::num(e)))
                                    .collect(),
                            ),
                        ),
                        ("ctrl_adaptive", Json::Bool(d.ctrl_adaptive)),
                        ("ctrl_lanes", Json::num(d.ctrl_lanes as f64)),
                        ("ctrl_depth", Json::num(d.ctrl_depth as f64)),
                        ("ctrl_reconfigs", Json::num(d.ctrl_reconfigs as f64)),
                        ("ctrl_evals", Json::num(d.ctrl_evals as f64)),
                        ("ctrl_utility", Json::num(d.ctrl_utility)),
                        (
                            "ctrl_utilities",
                            Json::Obj(
                                d.ctrl_utilities
                                    .iter()
                                    .map(|&(l, u)| (l.to_string(), Json::num(u)))
                                    .collect(),
                            ),
                        ),
                        ("cache_hits", Json::num(d.cache_hits as f64)),
                        ("cache_misses", Json::num(d.cache_misses as f64)),
                        ("cache_evictions", Json::num(d.cache_evictions as f64)),
                        ("cache_resident", Json::num(d.cache_resident as f64)),
                        ("flops", Json::num(d.flops)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema_version", Json::num(STATUS_SCHEMA_VERSION as f64)),
            ("tenants", tenants),
            ("devices", devices),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("throughput_rps", Json::num(self.throughput_rps())),
            ("throughput_flops", Json::num(self.throughput_flops())),
            (
                "superkernel_launches",
                Json::num(self.superkernel_launches as f64),
            ),
            ("kernel_launches", Json::num(self.kernel_launches as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
        ])
    }
}

/// Registry mapping tenant name → metrics, plus global counters.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    tenants: Mutex<BTreeMap<String, std::sync::Arc<TenantMetrics>>>,
    pub superkernel_launches: AtomicU64,
    pub kernel_launches: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the metrics handle for a tenant.
    pub fn tenant(&self, name: &str) -> std::sync::Arc<TenantMetrics> {
        let mut map = self.tenants.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(TenantMetrics::new()))
            .clone()
    }

    pub fn record_superkernel_launch(&self) {
        self.superkernel_launches.fetch_add(1, Ordering::Relaxed);
        self.kernel_launches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_kernel_launch(&self) {
        self.kernel_launches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_cache(&self, hit: bool) {
        if hit {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self, wall_seconds: f64) -> Snapshot {
        let map = self.tenants.lock().unwrap();
        Snapshot {
            tenants: map
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
            wall_seconds,
            superkernel_launches: self.superkernel_launches.load(Ordering::Relaxed),
            kernel_launches: self.kernel_launches.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            devices: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_records_and_snapshots() {
        let m = TenantMetrics::new();
        m.record_completion(1_000_000, 400_000, 1e9);
        m.record_completion(3_000_000, 500_000, 1e9);
        m.record_rejection();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.rejected, 1);
        assert!(s.latency_mean_ns > 1_000_000.0 && s.latency_mean_ns < 3_000_000.0);
        assert_eq!(s.flops, 2e9);
    }

    #[test]
    fn registry_reuses_tenant_handles() {
        let r = MetricsRegistry::new();
        let a = r.tenant("m0");
        let b = r.tenant("m0");
        a.record_completion(100, 50, 1.0);
        assert_eq!(b.snapshot().completed, 1);
    }

    #[test]
    fn snapshot_aggregates() {
        let r = MetricsRegistry::new();
        r.tenant("a").record_completion(1_000, 500, 100.0);
        r.tenant("b").record_completion(2_000, 900, 300.0);
        r.record_superkernel_launch();
        r.record_cache(true);
        r.record_cache(false);
        let s = r.snapshot(2.0);
        assert_eq!(s.total_completed(), 2);
        assert_eq!(s.total_flops(), 400.0);
        assert_eq!(s.throughput_rps(), 1.0);
        assert_eq!(s.superkernel_launches, 1);
        assert_eq!(s.kernel_launches, 1);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 1);
    }

    #[test]
    fn straggler_gap_computation() {
        let r = MetricsRegistry::new();
        // tenant a mean 1ms, tenant b mean 1.25ms → 25 % gap.
        r.tenant("a").record_completion(1_000_000, 1, 1.0);
        r.tenant("b").record_completion(1_250_000, 1, 1.0);
        let s = r.snapshot(1.0);
        assert!((s.straggler_gap() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn straggler_gap_single_tenant_is_zero() {
        let r = MetricsRegistry::new();
        r.tenant("only").record_completion(1_000, 1, 1.0);
        assert_eq!(r.snapshot(1.0).straggler_gap(), 0.0);
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let r = MetricsRegistry::new();
        r.tenant("a").record_completion(1_000, 500, 100.0);
        let j = r.snapshot(1.0).to_json();
        let text = j.to_string();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert!(back.get("tenants").is_some());
        assert_eq!(back.get("throughput_rps").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            back.get("schema_version").unwrap().as_f64(),
            Some(STATUS_SCHEMA_VERSION as f64)
        );
    }

    #[test]
    fn device_section_serializes() {
        let r = MetricsRegistry::new();
        let mut snap = r.snapshot(1.0);
        snap.devices = vec![DeviceSnapshot {
            device: 0,
            tenants: 2,
            pending: 1,
            launches: 7,
            superkernel_launches: 3,
            drained: 9,
            shed: 4,
            deadline_splits: 2,
            cost_calibration_error: 0.125,
            lane_launches: vec![4, 3],
            lane_busy_s: vec![0.5, 0.25],
            lane_steals: vec![0, 2],
            launch_retries: 1,
            lane_calibration: vec![(2, 0.0625)],
            ctrl_adaptive: true,
            ctrl_lanes: 2,
            ctrl_depth: 1,
            ctrl_reconfigs: 3,
            ctrl_evals: 11,
            ctrl_utility: 1500.0,
            ctrl_utilities: vec![(1, 1000.0), (2, 1500.0)],
            cache_hits: 6,
            cache_misses: 2,
            cache_evictions: 1,
            cache_resident: 1,
            flops: 1e9,
        }];
        let back = crate::util::json::Json::parse(&snap.to_json().to_string()).unwrap();
        let devices = back.get("devices").unwrap();
        let d0 = &devices.as_arr().unwrap()[0];
        assert_eq!(d0.get("launches").unwrap().as_f64(), Some(7.0));
        assert!(matches!(
            d0.get("ctrl_adaptive"),
            Some(crate::util::json::Json::Bool(true))
        ));
        assert_eq!(d0.get("ctrl_lanes").unwrap().as_f64(), Some(2.0));
        assert_eq!(d0.get("ctrl_depth").unwrap().as_f64(), Some(1.0));
        assert_eq!(d0.get("ctrl_reconfigs").unwrap().as_f64(), Some(3.0));
        assert_eq!(d0.get("ctrl_evals").unwrap().as_f64(), Some(11.0));
        assert_eq!(d0.get("ctrl_utility").unwrap().as_f64(), Some(1500.0));
        let utils = d0.get("ctrl_utilities").unwrap();
        assert_eq!(utils.get("1").unwrap().as_f64(), Some(1000.0));
        assert_eq!(utils.get("2").unwrap().as_f64(), Some(1500.0));
        assert_eq!(d0.get("shed").unwrap().as_f64(), Some(4.0));
        assert_eq!(d0.get("deadline_splits").unwrap().as_f64(), Some(2.0));
        assert_eq!(
            d0.get("cost_calibration_error").unwrap().as_f64(),
            Some(0.125)
        );
        let lanes = d0.get("lane_launches").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[1].as_f64(), Some(3.0));
        let busy = d0.get("lane_busy_s").unwrap().as_arr().unwrap();
        assert_eq!(busy[0].as_f64(), Some(0.5));
        let steals = d0.get("lane_steals").unwrap().as_arr().unwrap();
        assert_eq!(steals[1].as_f64(), Some(2.0));
        assert_eq!(d0.get("launch_retries").unwrap().as_f64(), Some(1.0));
        let calib = d0.get("lane_calibration").unwrap();
        assert_eq!(calib.get("2").unwrap().as_f64(), Some(0.0625));
        assert_eq!(d0.get("cache_hits").unwrap().as_f64(), Some(6.0));
        assert_eq!(d0.get("cache_misses").unwrap().as_f64(), Some(2.0));
        assert_eq!(d0.get("cache_evictions").unwrap().as_f64(), Some(1.0));
        assert_eq!(d0.get("cache_resident").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn lane_utilization_divides_by_wall() {
        let d = DeviceSnapshot {
            lane_busy_s: vec![1.0, 0.5],
            ..Default::default()
        };
        let u = d.lane_utilization(2.0);
        assert_eq!(u, vec![0.5, 0.25]);
        assert_eq!(d.lane_utilization(0.0), vec![0.0, 0.0]);
    }

    #[test]
    fn deadline_metrics_and_attainment() {
        let m = TenantMetrics::new();
        assert_eq!(m.snapshot().slo_attainment(), None);
        m.record_deadline(true);
        m.record_deadline(true);
        m.record_deadline(true);
        m.record_deadline(false);
        let s = m.snapshot();
        assert_eq!(s.deadline_hits, 3);
        assert_eq!(s.deadline_misses, 1);
        assert!((s.slo_attainment().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn attainment_serializes_to_json() {
        let r = MetricsRegistry::new();
        let t = r.tenant("a");
        t.record_completion(1_000, 500, 100.0);
        t.record_deadline(true);
        r.tenant("b").record_completion(1_000, 500, 100.0);
        let back =
            crate::util::json::Json::parse(&r.snapshot(1.0).to_json().to_string())
                .unwrap();
        let tenants = back.get("tenants").unwrap();
        let a = tenants.get("a").unwrap();
        assert_eq!(a.get("slo_attainment").unwrap().as_f64(), Some(1.0));
        assert_eq!(a.get("deadline_hits").unwrap().as_f64(), Some(1.0));
        // A tenant with no deadline verdicts serializes attainment as null.
        let b = tenants.get("b").unwrap();
        assert!(matches!(
            b.get("slo_attainment"),
            Some(crate::util::json::Json::Null)
        ));
    }
}
