//! `cargo xtask` — repo automation for stgpu (cargo-xtask convention).
//!
//! Subcommands:
//! * `lint [--root <dir>]` — run the repo-specific concurrency/perf lint
//!   pass over `rust/src` (see [`lint`] for the rules). Exits non-zero on
//!   any violation; CI runs this as a blocking job.
//!
//! Std-only by design: the offline environment vendors nothing for this
//! crate, and the lint is a line-oriented lexical scan, not a type-aware
//! analysis — cheap enough to run on every push.

#![forbid(unsafe_code)]

mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => {
            let mut root: Option<PathBuf> = None;
            loop {
                match args.next().as_deref() {
                    Some("--root") => match args.next() {
                        Some(dir) => root = Some(PathBuf::from(dir)),
                        None => {
                            eprintln!("xtask lint: --root needs a directory");
                            return ExitCode::from(2);
                        }
                    },
                    Some(other) => {
                        eprintln!("xtask lint: unknown flag {other:?}");
                        return ExitCode::from(2);
                    }
                    None => break,
                }
            }
            let root = root.unwrap_or_else(default_src_root);
            run_lint(&root)
        }
        Some(other) => {
            eprintln!("xtask: unknown command {other:?}");
            usage();
            ExitCode::from(2)
        }
        None => {
            usage();
            ExitCode::from(2)
        }
    }
}

fn usage() {
    eprintln!("usage: cargo run -p xtask -- lint [--root <dir>]");
}

/// The lint's default scope: the serving crate's sources (`rust/src`),
/// resolved relative to this crate so it works from any working directory.
/// Tests and benches are deliberately out of scope — they poison mutexes
/// and allocate on purpose.
fn default_src_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src")
}

fn run_lint(root: &std::path::Path) -> ExitCode {
    match lint::run(root) {
        Ok(report) => {
            for v in &report.violations {
                println!("{v}");
            }
            println!(
                "xtask lint: {} file(s) scanned, {} violation(s)",
                report.files, report.violations.len()
            );
            if report.violations.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask lint: {e}");
            ExitCode::from(2)
        }
    }
}
