//! The repo-specific lint pass: a line-oriented lexical scan encoding the
//! concurrency and hot-path conventions the serving crate relies on but
//! rustc/clippy cannot see.
//!
//! Rules:
//!
//! * `hot-path-alloc` — functions annotated `// lint: hot-path` are part of
//!   the allocation-free round loop; allocation-prone calls (`Vec::new`,
//!   `vec![`, `format!`, `.clone()`, `.collect()`, `.to_string()`, ...)
//!   are flagged inside them.
//! * `pure-clock` — functions annotated `// lint: pure` plan from an
//!   explicit `now: Instant` parameter; calling `Instant::now()` /
//!   `SystemTime::now()` / seeding an RNG inside them re-introduces the
//!   hidden-clock nondeterminism the planners were refactored to avoid.
//! * `pure-map-iter` — `// lint: pure` functions also feed the cluster
//!   tier's replayable decision journal, so any container they touch must
//!   have a deterministic iteration order: naming `HashMap`/`HashSet`
//!   inside them is flagged (use `BTreeMap`/`BTreeSet`, or sort before
//!   iterating and take the `// lint: allow(pure-map-iter)` escape with a
//!   reason).
//! * `lock-across-exec` — a `let`-bound mutex guard (`.lock()` /
//!   `lock_recover(`) must not be live across a launch execution or weight
//!   marshal (`.execute(`, `execute_prepared(`, `resolve_weights(`):
//!   holding the fusion-cache or cost-model lock through device work is
//!   the serialization bug the lane pipeline exists to avoid. The guard
//!   dies at its scope's closing brace or an explicit `drop(guard)`.
//! * `ordering-comment` — every non-`Relaxed` atomic operation
//!   (`Ordering::Acquire/Release/AcqRel/SeqCst`) must carry an
//!   `// ordering:` comment on the same line or within the 3 lines above
//!   it, naming what it pairs with (see `SnapshotMirror`'s seqlock).
//! * `unsafe-safety` — every `unsafe` item needs a `// SAFETY:` comment
//!   within the 5 lines above it (the crate is `#![deny(unsafe_code)]`;
//!   the per-site `#[allow]`s form the documented allowlist).
//!
//! Escape hatch: `// lint: allow(<rule>)` on the offending line or in the
//! comment block directly above it suppresses that one rule through the
//! end of the next statement (so a multi-line method chain stays covered —
//! see the batcher's per-launch entry vector for the idiom).
//!
//! `#[cfg(test)]` items are skipped entirely — tests poison mutexes and
//! allocate on purpose.
//!
//! This is a lexical scan, not a semantic analysis: it sees tokens, not
//! types. The conventions it enforces are annotation-driven precisely so
//! that a match is meaningful without type information.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    HotPathAlloc,
    PureClock,
    PureMapIter,
    LockAcrossExec,
    OrderingComment,
    UnsafeSafety,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::HotPathAlloc => "hot-path-alloc",
            Rule::PureClock => "pure-clock",
            Rule::PureMapIter => "pure-map-iter",
            Rule::LockAcrossExec => "lock-across-exec",
            Rule::OrderingComment => "ordering-comment",
            Rule::UnsafeSafety => "unsafe-safety",
        }
    }
}

#[derive(Debug)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule.name(), self.message)
    }
}

pub struct Report {
    pub files: usize,
    pub violations: Vec<Violation>,
}

/// Lint every `.rs` file under `root` (recursively), skipping `vendor/`
/// and `target/` trees.
pub fn run(root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files).map_err(|e| format!("walking {}: {e}", root.display()))?;
    files.sort();
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }
    let mut violations = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f).map_err(|e| format!("{}: {e}", f.display()))?;
        violations.extend(lint_source(&f.display().to_string(), &src));
    }
    Ok(Report { files: files.len(), violations })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "vendor" || name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Allocation-prone call tokens flagged inside `// lint: hot-path` bodies.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new(",
    "vec![",
    "String::new(",
    "String::from(",
    ".to_string(",
    ".to_owned(",
    "format!(",
    "Box::new(",
    ".collect(",
    ".clone(",
    "HashMap::new(",
    "BTreeMap::new(",
    "VecDeque::new(",
];

/// Hidden-clock / hidden-randomness tokens flagged inside `// lint: pure`
/// bodies.
const CLOCK_TOKENS: &[&str] = &["Instant::now(", "SystemTime::now(", "Rng::new(", "rand::"];

/// Unordered-container tokens flagged inside `// lint: pure` bodies:
/// their iteration order varies run-to-run, which would leak into the
/// replayable decision journal. Use `BTreeMap`/`BTreeSet` instead.
const MAP_TOKENS: &[&str] = &["HashMap<", "HashMap::", "HashSet<", "HashSet::"];

/// Device-work calls a lock guard must not be live across.
const EXEC_TOKENS: &[&str] = &[".execute(", "execute_prepared(", "resolve_weights("];

/// Non-Relaxed atomic orderings that require an `// ordering:` comment.
const ORDERING_TOKENS: &[&str] = &[
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

/// A function context opened by `// lint:` markers.
struct FnCtx {
    hot: bool,
    pure: bool,
    /// Depth of the function's body block once `{` is seen; the context
    /// is armed (body_depth == None) between the `fn` keyword and the
    /// opening brace, so multi-line signatures attach correctly.
    body_depth: Option<i32>,
}

/// A `let`-bound mutex guard believed live.
struct Guard {
    name: String,
    depth: i32,
    line: usize,
}

/// Lint one file's source. `path` is used only for reporting.
pub fn lint_source(path: &str, src: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    let lines: Vec<&str> = src.lines().collect();
    let mut depth: i32 = 0;
    let mut in_block_comment = false;
    let mut fn_stack: Vec<FnCtx> = Vec::new();
    let mut guards: Vec<Guard> = Vec::new();
    // `// lint:` markers and allows accumulated from the comment block
    // directly above the current code line.
    let mut pending_hot = false;
    let mut pending_pure = false;
    let mut pending_allows: Vec<Rule> = Vec::new();
    // Depth below which we are inside a `#[cfg(test)]` item (skip checks).
    let mut cfg_test_pending = false;
    let mut test_skip_depth: Option<i32> = None;

    for (idx, raw) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let (code, comment) = split_code_comment(raw, &mut in_block_comment);

        // Markers live in comments; collect them whether or not the line
        // also has code (a trailing `// lint: allow(..)` applies to its
        // own line).
        let mut line_allows = pending_allows.clone();
        if let Some(rest) = comment_directive(&comment) {
            for part in rest.split(',') {
                let part = part.trim();
                if part == "hot-path" {
                    pending_hot = true;
                } else if part == "pure" {
                    pending_pure = true;
                } else if let Some(rule) = part
                    .strip_prefix("allow(")
                    .and_then(|r| r.strip_suffix(')'))
                    .and_then(rule_by_name)
                {
                    pending_allows.push(rule);
                    line_allows.push(rule);
                }
            }
        }

        let in_test = test_skip_depth.is_some();
        let has_code = !code.trim().is_empty();

        if has_code && !in_test {
            if code.contains("#[cfg(test)]") {
                cfg_test_pending = true;
            }
            run_checks(
                path,
                lineno,
                &code,
                raw,
                &lines[..idx],
                &fn_stack,
                &guards,
                &line_allows,
                &mut out,
            );
            // Attach pending fn markers to this line's `fn`.
            if (pending_hot || pending_pure) && has_fn_keyword(&code) {
                fn_stack.push(FnCtx {
                    hot: pending_hot,
                    pure: pending_pure,
                    body_depth: None,
                });
                pending_hot = false;
                pending_pure = false;
            }
            // Track new lock guards (let-bound on this line).
            if (code.contains(".lock(") || code.contains("lock_recover("))
                && code.contains("let ")
            {
                if let Some(name) = let_binding_name(&code) {
                    guards.push(Guard { name, depth, line: lineno });
                }
            }
            // An explicit drop releases the guard early.
            guards.retain(|g| !code.contains(&format!("drop({})", g.name)));
        }

        // Brace accounting (always, so test-module scopes close properly).
        for ch in code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if cfg_test_pending && test_skip_depth.is_none() {
                        test_skip_depth = Some(depth);
                        cfg_test_pending = false;
                    }
                    if let Some(ctx) = fn_stack.last_mut() {
                        if ctx.body_depth.is_none() {
                            ctx.body_depth = Some(depth);
                        }
                    }
                }
                '}' => {
                    depth -= 1;
                    if test_skip_depth.is_some_and(|d| depth < d) {
                        test_skip_depth = None;
                    }
                    while fn_stack
                        .last()
                        .and_then(|c| c.body_depth)
                        .is_some_and(|d| depth < d)
                    {
                        fn_stack.pop();
                    }
                    guards.retain(|g| g.depth <= depth);
                }
                _ => {}
            }
        }

        if has_code {
            // Allows persist through the end of the statement they cover,
            // so a multi-line method chain under one escape stays covered.
            if code.contains(';') || code.contains('{') {
                pending_allows.clear();
            }
            if !has_fn_keyword(&code) {
                // Markers separated from their `fn` by unrelated code are
                // stale; drop them so they cannot leak onto a later item.
                pending_hot = false;
                pending_pure = false;
            }
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn run_checks(
    path: &str,
    lineno: usize,
    code: &str,
    raw: &str,
    above: &[&str],
    fn_stack: &[FnCtx],
    guards: &[Guard],
    allows: &[Rule],
    out: &mut Vec<Violation>,
) {
    let allowed = |r: Rule| allows.contains(&r);
    let hot = fn_stack.iter().any(|c| c.hot && c.body_depth.is_some());
    let pure = fn_stack.iter().any(|c| c.pure && c.body_depth.is_some());

    if hot && !allowed(Rule::HotPathAlloc) {
        for t in ALLOC_TOKENS {
            if code.contains(t) {
                out.push(Violation {
                    file: path.to_string(),
                    line: lineno,
                    rule: Rule::HotPathAlloc,
                    message: format!(
                        "`{t}` in a `// lint: hot-path` function (the round \
                         loop is allocation-free; recycle a buffer or add \
                         `// lint: allow(hot-path-alloc)` with a reason)"
                    ),
                });
                break;
            }
        }
    }

    if pure && !allowed(Rule::PureClock) {
        for t in CLOCK_TOKENS {
            if code.contains(t) {
                out.push(Violation {
                    file: path.to_string(),
                    line: lineno,
                    rule: Rule::PureClock,
                    message: format!(
                        "`{t}` in a `// lint: pure` function (planners take \
                         `now` as a parameter; a hidden clock or RNG breaks \
                         replay determinism)"
                    ),
                });
                break;
            }
        }
    }

    if pure && !allowed(Rule::PureMapIter) {
        for t in MAP_TOKENS {
            if code.contains(t) {
                out.push(Violation {
                    file: path.to_string(),
                    line: lineno,
                    rule: Rule::PureMapIter,
                    message: format!(
                        "`{t}` in a `// lint: pure` function (hash iteration \
                         order is nondeterministic and would leak into the \
                         replayable journal; use BTreeMap/BTreeSet or sort \
                         first and add `// lint: allow(pure-map-iter)` with \
                         a reason)"
                    ),
                });
                break;
            }
        }
    }

    if !guards.is_empty() && !allowed(Rule::LockAcrossExec) {
        for t in EXEC_TOKENS {
            if code.contains(t) {
                let g = guards.last().expect("non-empty");
                out.push(Violation {
                    file: path.to_string(),
                    line: lineno,
                    rule: Rule::LockAcrossExec,
                    message: format!(
                        "`{t}` while the guard `{}` (line {}) is live — \
                         device work must not run under a mutex; drop the \
                         guard first",
                        g.name, g.line
                    ),
                });
                break;
            }
        }
    }

    if !allowed(Rule::OrderingComment) && !code.trim_start().starts_with("use ") {
        for t in ORDERING_TOKENS {
            if code.contains(t) {
                let documented = raw.contains("ordering:")
                    || above.iter().rev().take(3).any(|l| l.contains("ordering:"));
                if !documented {
                    out.push(Violation {
                        file: path.to_string(),
                        line: lineno,
                        rule: Rule::OrderingComment,
                        message: format!(
                            "`{t}` without an `// ordering:` comment (same \
                             line or the 3 above) saying what it pairs with"
                        ),
                    });
                }
                break;
            }
        }
    }

    if !allowed(Rule::UnsafeSafety) && code.contains("unsafe ") {
        let documented = raw.contains("SAFETY:")
            || above.iter().rev().take(5).any(|l| l.contains("SAFETY:"));
        if !documented {
            out.push(Violation {
                file: path.to_string(),
                line: lineno,
                rule: Rule::UnsafeSafety,
                message: "`unsafe` without a `// SAFETY:` comment within the \
                          5 lines above it"
                    .to_string(),
            });
        }
    }
}

fn rule_by_name(s: &str) -> Option<Rule> {
    Some(match s {
        "hot-path-alloc" => Rule::HotPathAlloc,
        "pure-clock" => Rule::PureClock,
        "pure-map-iter" => Rule::PureMapIter,
        "lock-across-exec" => Rule::LockAcrossExec,
        "ordering-comment" => Rule::OrderingComment,
        "unsafe-safety" => Rule::UnsafeSafety,
        _ => return None,
    })
}

/// The `lint:` directive payload of a comment, if present.
fn comment_directive(comment: &str) -> Option<&str> {
    let at = comment.find("lint:")?;
    Some(comment[at + "lint:".len()..].trim())
}

/// Does this code text contain the `fn` keyword (not as part of another
/// identifier)?
fn has_fn_keyword(code: &str) -> bool {
    for (i, _) in code.match_indices("fn ") {
        let before_ok = i == 0
            || !code.as_bytes()[i - 1].is_ascii_alphanumeric()
                && code.as_bytes()[i - 1] != b'_';
        if before_ok {
            return true;
        }
    }
    false
}

/// The binding name of a `let` statement (`let mut name = ...`), if the
/// pattern is a plain identifier.
fn let_binding_name(code: &str) -> Option<String> {
    let at = code.find("let ")?;
    let rest = code[at + 4..].trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let name: String = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Split one line into (code, comment) with string/char literals blanked
/// out of the code part, tracking `/* */` across lines. Blanking literals
/// keeps brace counting and token matching honest (`"{"`, `'{'`, or a
/// token inside a string must not count).
fn split_code_comment(raw: &str, in_block_comment: &mut bool) -> (String, String) {
    let bytes = raw.as_bytes();
    let mut code = String::with_capacity(raw.len());
    let mut comment = String::new();
    let mut i = 0;
    // All lookahead is byte-wise (never slicing `raw` mid-character), so a
    // multibyte character in a comment or identifier cannot panic the scan.
    while i < bytes.len() {
        if *in_block_comment {
            if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                *in_block_comment = false;
                i += 2;
            } else {
                comment.push(bytes[i] as char);
                i += 1;
            }
            continue;
        }
        if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'/') {
            comment.push_str(&raw[i..]);
            break;
        }
        if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
            *in_block_comment = true;
            i += 2;
            continue;
        }
        match bytes[i] {
            b'"' => {
                // Skip the string literal, honoring escapes.
                code.push(' ');
                i += 1;
                while i < bytes.len() {
                    if bytes[i] == b'\\' {
                        i += 2;
                    } else if bytes[i] == b'"' {
                        i += 1;
                        break;
                    } else {
                        i += 1;
                    }
                }
            }
            b'\'' => {
                // Char literal ('x', '\n', '\u{..}') vs lifetime ('a).
                let lit_end = char_literal_end(raw, i);
                match lit_end {
                    Some(end) => {
                        code.push(' ');
                        i = end;
                    }
                    None => {
                        code.push('\'');
                        i += 1;
                    }
                }
            }
            b => {
                code.push(b as char);
                i += 1;
            }
        }
    }
    (code, comment)
}

/// If a char literal starts at byte `i` (which holds `'`), return the index
/// one past its closing quote; `None` if this is a lifetime.
fn char_literal_end(raw: &str, i: usize) -> Option<usize> {
    let bytes = raw.as_bytes();
    let mut j = i + 1;
    if j >= bytes.len() {
        return None;
    }
    if bytes[j] == b'\\' {
        // Escape: scan to the next unescaped quote.
        j += 2;
        while j < bytes.len() && bytes[j] != b'\'' {
            j += 1;
        }
        return (j < bytes.len()).then_some(j + 1);
    }
    // 'x' is a char literal only if the quote closes right after one char.
    let ch_len = raw[j..].chars().next().map(char::len_utf8)?;
    let close = j + ch_len;
    (bytes.get(close) == Some(&b'\'')).then_some(close + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(src: &str) -> Vec<Violation> {
        lint_source("fixture.rs", src)
    }

    fn rules(v: &[Violation]) -> Vec<Rule> {
        v.iter().map(|x| x.rule).collect()
    }

    /// The acceptance fixture: a seeded allocation in a hot-path function
    /// must be flagged.
    #[test]
    fn seeded_hot_path_allocation_is_flagged() {
        let src = r#"
// lint: hot-path
fn round_step(&mut self) {
    let staging: Vec<u64> = Vec::new();
    self.consume(staging);
}
"#;
        let v = lint(src);
        assert_eq!(rules(&v), vec![Rule::HotPathAlloc], "{v:?}");
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn unannotated_function_may_allocate() {
        let src = "fn cold_setup() { let v: Vec<u64> = Vec::new(); drop(v); }";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn allow_escape_suppresses_one_site() {
        let src = r#"
// lint: hot-path
fn round_step(&mut self) {
    // lint: allow(hot-path-alloc) — entries are carried away by value.
    let chunk: Vec<u64> = reqs.drain(..take).collect();
    let second: Vec<u64> = Vec::new();
}
"#;
        let v = lint(src);
        assert_eq!(rules(&v), vec![Rule::HotPathAlloc]);
        assert_eq!(v[0].line, 6, "only the unescaped site is flagged");
    }

    #[test]
    fn allow_escape_covers_a_multiline_statement() {
        let src = r#"
// lint: hot-path
fn round_step(&mut self) {
    // lint: allow(hot-path-alloc) — POD enum, a few-word copy.
    let spec = self
        .tenants
        .get(first.tenant)
        .spec
        .clone();
    let second = spec.clone();
}
"#;
        let v = lint(src);
        assert_eq!(rules(&v), vec![Rule::HotPathAlloc]);
        assert_eq!(v[0].line, 10, "the chain is covered; the next statement is not");
    }

    #[test]
    fn hot_path_scope_ends_at_function_close() {
        let src = r#"
// lint: hot-path
fn tight(&self) -> usize {
    self.len
}

fn relaxed(&self) -> String {
    format!("{}", self.len)
}
"#;
        assert!(lint(src).is_empty());
    }

    /// The vectorized engine's idiom (`gpusim/engine.rs`): the annotated
    /// round loop stays allocation-free by routing trace-label
    /// construction into an *unannotated* record helper whose
    /// `record_with` closure only runs when capture is enabled. The
    /// helper may allocate; the hot loop may not; and a rustc/clippy
    /// attribute above the marker still arms the context.
    #[test]
    fn engine_record_helper_pattern_is_clean_but_inlined_label_is_not() {
        let clean = r#"
fn record_kernel(trace: &mut Trace, k: &KernelDesc, t0: f64, t1: f64) {
    trace.record_with(|| TraceEvent { label: k.name.clone(), t0, t1 });
}

#[allow(clippy::too_many_arguments)]
// lint: hot-path
fn space_time_rounds(&mut self) {
    self.clock += self.dur;
    record_kernel(&mut self.trace, &self.k, 0.0, self.clock);
}
"#;
        let v = lint(clean);
        assert!(v.is_empty(), "helper-routed labels must pass: {v:?}");
        let dirty = r#"
#[allow(clippy::too_many_arguments)]
// lint: hot-path
fn space_time_rounds(&mut self) {
    let label = self.k.name.clone();
    self.consume(label);
}
"#;
        let v = lint(dirty);
        assert_eq!(rules(&v), vec![Rule::HotPathAlloc], "{v:?}");
        assert_eq!(v[0].line, 5, "the inlined clone is the flagged site");
    }

    #[test]
    fn pure_function_must_not_read_the_clock() {
        let src = r#"
// lint: pure
fn plan(&mut self, now: Instant) {
    let t = Instant::now();
}
"#;
        assert_eq!(rules(&lint(src)), vec![Rule::PureClock]);
    }

    /// The acceptance fixture: a seeded bare-HashMap use inside a
    /// `// lint: pure` function must be flagged.
    #[test]
    fn seeded_pure_hashmap_iteration_is_flagged() {
        let src = r#"
// lint: pure
fn issue_round(&mut self, round: u64) -> Vec<Cmd> {
    let mut pending: HashMap<usize, Vec<Cmd>> = HashMap::new();
    for (node, cmds) in &pending {
        self.emit(*node, cmds);
    }
    Vec::new()
}
"#;
        let v = lint(src);
        assert_eq!(rules(&v), vec![Rule::PureMapIter], "{v:?}");
        assert_eq!(v[0].line, 4, "the declaration is the first flagged site");
    }

    #[test]
    fn pure_btreemap_is_deterministic_and_clean() {
        let src = r#"
// lint: pure
fn issue_round(&mut self, round: u64) -> Vec<Cmd> {
    let mut pending: BTreeMap<usize, Vec<Cmd>> = BTreeMap::new();
    for (node, cmds) in &pending {
        self.emit(*node, cmds);
    }
    Vec::new()
}
"#;
        assert!(lint(src).is_empty());
    }

    #[test]
    fn unannotated_function_may_use_hashmap() {
        let src = "fn cold(&self) { let m: HashMap<u32, u32> = HashMap::new(); drop(m); }";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn pure_map_iter_allow_escape_works() {
        let src = r#"
// lint: pure
fn plan(&self, now: Instant) {
    // lint: allow(pure-map-iter) — keys are sorted into a Vec below.
    let mut keys: Vec<u32> = self.index.keys().copied().collect::<HashSet<u32>>().into_iter().collect();
    keys.sort_unstable();
}
"#;
        assert!(lint(src).is_empty());
    }

    #[test]
    fn guard_live_across_execute_is_flagged() {
        let src = r#"
fn launch(&self) {
    let cache = self.cache.lock().unwrap();
    self.engine.execute(&cache.plan);
}
"#;
        assert_eq!(rules(&lint(src)), vec![Rule::LockAcrossExec]);
    }

    #[test]
    fn guard_scoped_out_before_execute_is_fine() {
        let src = r#"
fn launch(&self) {
    let stats = {
        let cache = lock_recover(&self.cache);
        cache.stats
    };
    self.engine.execute(stats);
}
"#;
        assert!(lint(src).is_empty());
    }

    #[test]
    fn explicit_drop_releases_the_guard() {
        let src = r#"
fn launch(&self) {
    let cost = lock_recover(&self.cost);
    let dur = cost.predict();
    drop(cost);
    self.engine.execute(dur);
}
"#;
        assert!(lint(src).is_empty());
    }

    #[test]
    fn non_relaxed_ordering_needs_a_comment() {
        let bad = "fn f(a: &AtomicU64) { a.store(1, Ordering::Release); }";
        assert_eq!(rules(&lint(bad)), vec![Rule::OrderingComment]);
        let good = r#"
fn f(a: &AtomicU64) {
    // ordering: Release store — pairs with the reader's Acquire load.
    a.store(1, Ordering::Release);
}
"#;
        assert!(lint(good).is_empty());
        let relaxed = "fn f(a: &AtomicU64) { a.store(1, Ordering::Relaxed); }";
        assert!(lint(relaxed).is_empty(), "Relaxed needs no comment");
        let import = "use std::sync::atomic::Ordering::Release;";
        assert!(lint(import).is_empty(), "imports are not operations");
    }

    #[test]
    fn unsafe_needs_a_safety_comment() {
        let bad = "unsafe impl Send for Thing {}";
        assert_eq!(rules(&lint(bad)), vec![Rule::UnsafeSafety]);
        let good = r#"
// SAFETY: Thing's pointer is only dereferenced under the owner's lock.
unsafe impl Send for Thing {}
"#;
        assert!(lint(good).is_empty());
    }

    #[test]
    fn cfg_test_modules_are_skipped() {
        let src = r#"
// lint: hot-path
fn tight(&self) -> usize {
    self.len
}

#[cfg(test)]
mod tests {
    fn helper() {
        let g = m.lock().unwrap();
        engine.execute(&g);
        let v: Vec<u64> = Vec::new();
    }
}
"#;
        assert!(lint(src).is_empty());
    }

    #[test]
    fn strings_and_char_literals_do_not_confuse_the_scanner() {
        let src = r#"
// lint: hot-path
fn tight(&self) {
    let open = '{';
    let close = '}';
    let msg = "Vec::new( } { .clone(";
    self.push(open, close, msg);
}
"#;
        assert!(lint(src).is_empty());
    }

    #[test]
    fn multiline_signature_attaches_to_the_marker() {
        let src = r#"
// lint: hot-path
fn dispatch(
    &mut self,
    item: Item,
) -> bool {
    let tag = item.spec.clone();
    self.send(tag)
}
"#;
        assert_eq!(rules(&lint(src)), vec![Rule::HotPathAlloc]);
    }
}
