//! Table 1 — space-time scheduling throughput increase over the
//! next-best approach, per shape class.
//!
//! Paper claims (V100, R SGEMM problems queued):
//!   RNN matvec  (512×1×512):    R=10 → 1.21x, R=20 → 2.14x, geomean 2.48x (next best: time-only)
//!   conv2_2     (256×128×1152): R=10 → 1.68x, R=20 → 2.88x, geomean 3.23x (next best: space-only)
//!   square      (256×256×256):  R=10 → 2.42x, R=20 → 2.47x, geomean 4.93x (next best: space-only)
//!
//! Regenerates every cell: speedup of space-time over the better of
//! time-only/space-only at R=10, R=20 and the geomean over 2 ≤ R ≤ 120.

use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::util::bench::{banner, Table};
use stgpu::util::stats::geomean;
use stgpu::workload::sgemm_tenants;

fn throughput(spec: &DeviceSpec, policy: Policy, r: usize, shape: GemmShape) -> f64 {
    let cfg = SimConfig::new(spec.clone(), policy);
    gpusim::run(&cfg, &sgemm_tenants(r, 16, shape)).throughput_flops()
}

fn main() {
    banner(
        "Table 1: space-time speedup over the next-best scheduler",
        "RNN 2.48x (vs time), conv2_2 3.23x (vs space), square 4.93x (vs space), geomean 2<=R<=120",
    );
    let spec = DeviceSpec::v100();
    let shapes = [
        ("rnn_matvec 512x1x512", GemmShape::RNN_MATVEC, "time-only", 1.21, 2.14, 2.48),
        ("conv2_2 256x128x1152", GemmShape::RESNET18_CONV2_2, "space-only", 1.68, 2.88, 3.23),
        ("square 256x256x256", GemmShape::SQUARE_256, "space-only", 2.42, 2.47, 4.93),
    ];
    let geomean_rs = [2usize, 5, 10, 20, 40, 60, 80, 100, 120];

    let mut table = Table::new(&[
        "shape", "R=10", "paper", "R=20", "paper ", "geomean(2..120)", "paper  ", "next_best",
    ]);
    for (name, shape, paper_next, p10, p20, pgeo) in shapes {
        let speedup = |r: usize| {
            let st = throughput(&spec, Policy::SpaceTime { max_batch: 128 }, r, shape);
            let time = throughput(&spec, Policy::TimeMux, r, shape);
            let space = throughput(&spec, Policy::SpaceMuxMps { anomaly_seed: 11 }, r, shape);
            (st / time.max(space), time >= space)
        };
        let (s10, _) = speedup(10);
        let (s20, _) = speedup(20);
        let all: Vec<f64> = geomean_rs.iter().map(|&r| speedup(r).0).collect();
        // Which baseline wins over the sweep (the paper's "next best")?
        let mut time_wins = 0;
        for &r in &geomean_rs {
            let time = throughput(&spec, Policy::TimeMux, r, shape);
            let space = throughput(&spec, Policy::SpaceMuxMps { anomaly_seed: 11 }, r, shape);
            if time >= space {
                time_wins += 1;
            }
        }
        let next_best = if time_wins * 2 > geomean_rs.len() { "time-only" } else { "space-only" };
        table.row(&[
            name.to_string(),
            format!("{s10:.2}x"),
            format!("{p10:.2}x"),
            format!("{s20:.2}x"),
            format!("{p20:.2}x"),
            format!("{:.2}x", geomean(&all)),
            format!("{pgeo:.2}x"),
            format!("{next_best} (paper: {paper_next})"),
        ]);
    }
    table.emit("table1_speedups");
    println!(
        "shape check: speedups grow with R for every class; matvec (BW-bound)\n\
         gains least, square (compute-dense) gains most — the paper's ordering."
    );
}
