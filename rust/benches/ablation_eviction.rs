//! Ablation — straggler eviction (paper §4 claim).
//!
//! "CUDA Stream scheduling anomalies typically only create a few
//! stragglers, so we can simply evict degraded workers without
//! significantly impacting total system throughput."
//!
//! Measures, with an injected MPS-style straggler (1.25x slow tenant):
//!   * evictor OFF: the straggler drags the fastest-vs-slowest gap up and
//!     holds p99 hostage;
//!   * evictor ON: the straggler is removed after `strikes` windows; the
//!     surviving tenants' gap collapses and aggregate throughput loses at
//!     most ~1/N.

use stgpu::coordinator::{MonitorConfig, SloMonitor, TenantRegistry};
use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::util::bench::{banner, Table};
use stgpu::workload::sgemm_tenants;

/// Simulated closed-loop windows with a deterministic straggler; returns
/// (windows to eviction, gap before, gap after, throughput retention).
fn run_eviction(n: usize, slow_factor: f64, enabled: bool) -> (Option<u32>, f64, f64, f64) {
    let mut reg = TenantRegistry::new();
    for i in 0..n {
        reg.register(&format!("t{i}"), "sgemm:256x128x1152", 100.0, i as u64)
            .unwrap();
    }
    let mut mon = SloMonitor::new(
        MonitorConfig { enabled, threshold: 1.15, strikes: 3, ..Default::default() },
        &reg,
    );
    let straggler = n - 1;
    let base_s = 2e-3;
    let mut evicted_at = None;
    let windows = 12u32;
    let mut completed_healthy = 0u64;
    let mut completed_total = 0u64;
    for w in 0..windows {
        for t in 0..n {
            if !reg.get(t).unwrap().is_servable() {
                continue;
            }
            let lat = if t == straggler { base_s * slow_factor } else { base_s };
            for _ in 0..8 {
                mon.observe(t, lat);
                completed_total += 1;
                if t != straggler {
                    completed_healthy += 1;
                }
            }
        }
        let evs = mon.check(&mut reg);
        if evicted_at.is_none() && !evs.is_empty() {
            evicted_at = Some(w + 1);
        }
    }
    let gap_before = slow_factor - 1.0;
    let gap_after = if evicted_at.is_some() { 0.0 } else { gap_before };
    // Throughput retention vs the no-straggler ideal (healthy tenants only
    // keep completing; the evicted tenant's share is the only loss).
    let ideal = (windows as u64) * 8 * (n as u64);
    let retention = if evicted_at.is_some() {
        completed_total as f64 / ideal as f64
    } else {
        // Straggler keeps running slow: effective completion-rate loss.
        (completed_healthy as f64 + (windows as u64 * 8) as f64 / slow_factor)
            / ideal as f64
    };
    (evicted_at, gap_before, gap_after, retention)
}

fn main() {
    banner(
        "Ablation: straggler eviction on/off",
        "evicting degraded workers restores predictability without significant throughput loss",
    );
    let mut table = Table::new(&[
        "tenants", "evictor", "evicted_after_windows", "gap_before_%", "gap_after_%", "throughput_retention_%",
    ]);
    for n in [4usize, 8, 12] {
        for enabled in [false, true] {
            let (at, gb, ga, ret) = run_eviction(n, 1.25, enabled);
            table.row(&[
                n.to_string(),
                if enabled { "ON".into() } else { "off".into() },
                at.map(|w| w.to_string()).unwrap_or_else(|| "-".into()),
                format!("{:.0}", gb * 100.0),
                format!("{:.0}", ga * 100.0),
                format!("{:.1}", ret * 100.0),
            ]);
        }
    }
    table.emit("ablation_eviction");

    // Device-level cross-check: removing one tenant of N costs ≈ 1/N of
    // aggregate simulated throughput under space-time.
    let spec = DeviceSpec::v100();
    let shape = GemmShape::RESNET18_CONV2_2;
    let tput = |n: usize| {
        let cfg = SimConfig::new(spec.clone(), Policy::SpaceTime { max_batch: 64 });
        gpusim::run(&cfg, &sgemm_tenants(n, 16, shape)).throughput_flops()
    };
    let full = tput(8);
    let after = tput(7);
    println!(
        "device check: evicting 1 of 8 tenants keeps {:.1}% of space-time \
         throughput (paper: 'without significantly impacting total system throughput')",
        after / full * 100.0
    );
}
