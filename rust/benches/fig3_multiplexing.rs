//! Figure 3 — time vs spatial multiplexing latency as tenants are added.
//!
//! Paper claim: neither matches exclusive access; time-only multiplexing is
//! a geomean 4.6x slower than exclusive, space-only 2.2x, across the
//! MobileNetV2/ResNet-50 grid; time-mux latency grows ~linearly with the
//! number of tenants.
//!
//! Regenerates both panels: per-model inference latency for 1..16 tenants
//! under exclusive / time-mux / space-mux (MPS).

use stgpu::gpusim::{self, DeviceSpec, Policy, SimConfig};
use stgpu::models::zoo;
use stgpu::util::bench::{banner, fmt_secs, BenchJson, Table};
use stgpu::util::stats::{geomean, percentile};
use stgpu::workload::model_tenants;

fn main() {
    banner(
        "Figure 3: inference latency vs tenants (MobileNetV2, ResNet-50)",
        "time-mux geomean 4.6x slower than exclusive; space-mux 2.2x",
    );
    let spec = DeviceSpec::v100();
    let batch = 8;
    let iters = 8;
    let tenant_counts = [1usize, 2, 4, 8, 12, 16];

    let mut ratios_time = Vec::new();
    let mut ratios_space = Vec::new();
    let mut all_lats = Vec::new();

    for model in [zoo::mobilenet_v2(), zoo::resnet50()] {
        let mut table = Table::new(&["tenants", "exclusive", "time-mux", "space-mux(MPS)", "time/excl", "space/excl"]);
        for &n in &tenant_counts {
            let lat = |policy: Policy| {
                let cfg = SimConfig::new(spec.clone(), policy);
                gpusim::run(&cfg, &model_tenants(n, iters, &model, batch)).mean_latency()
            };
            let excl = lat(Policy::Exclusive);
            let time = lat(Policy::TimeMux);
            let space = lat(Policy::SpaceMuxMps { anomaly_seed: 42 });
            all_lats.extend([excl, time, space]);
            if n > 1 {
                ratios_time.push(time / excl);
                ratios_space.push(space / excl);
            }
            table.row(&[
                n.to_string(),
                fmt_secs(excl),
                fmt_secs(time),
                fmt_secs(space),
                format!("{:.2}x", time / excl),
                format!("{:.2}x", space / excl),
            ]);
        }
        println!("--- {} (batch {batch}) ---", model.name);
        table.emit(&format!("fig3_{}", model.name));
    }

    println!(
        "geomean slowdown vs exclusive — time-mux: {:.2}x (paper 4.6x), \
         space-mux: {:.2}x (paper 2.2x)",
        geomean(&ratios_time),
        geomean(&ratios_space)
    );
    println!("shape check: time-mux grows ~linearly; space-mux sits between.");
    BenchJson::new("fig3_multiplexing")
        .p50_s(percentile(&all_lats, 50.0))
        .p99_s(percentile(&all_lats, 99.0))
        .write();
}
