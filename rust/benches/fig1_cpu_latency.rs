//! Figure 1 — CPU inference latency rises across model generations.
//!
//! Paper claim: batch-1 CPU inference latency grows with model
//! year/complexity; SENet-184 reaches ~4.1 s, far outside interactive SLOs.
//!
//! Regenerates the figure's series: (model, year, GFLOPs, CPU latency) on
//! the Xeon-class serving device model.

use stgpu::gpusim::{self, DeviceSpec, Policy, SimConfig};
use stgpu::models::zoo;
use stgpu::util::bench::{banner, fmt_secs, BenchJson, Table};
use stgpu::util::stats;
use stgpu::workload::model_tenants;

fn main() {
    banner(
        "Figure 1: CPU inference latency by model generation",
        "latency rises across generations; SENet-184 ~4.1 s on CPU",
    );
    let cpu = DeviceSpec::cpu_xeon();
    let slo_ms = 100.0;
    let mut table = Table::new(&["model", "year", "GFLOPs", "cpu_latency", "over_slo_x"]);
    let mut lats = Vec::new();
    for model in zoo::figure1_lineup() {
        let cfg = SimConfig::new(cpu.clone(), Policy::Exclusive);
        let report = gpusim::run(&cfg, &model_tenants(1, 1, &model, 1));
        let lat = report.mean_latency();
        lats.push(lat);
        table.row(&[
            model.name.clone(),
            model.year.to_string(),
            format!("{:.2}", model.flops(1) / 1e9),
            fmt_secs(lat),
            format!("{:.1}", lat * 1e3 / slo_ms),
        ]);
    }
    table.emit("fig1_cpu_latency");
    BenchJson::new("fig1_cpu_latency")
        .p50_s(stats::percentile(&lats, 50.0))
        .p99_s(stats::percentile(&lats, 99.0))
        .write();
    println!(
        "shape check: latency grows monotonically-ish with generation; the\n\
         2018 endpoint sits ~4 s — orders of magnitude beyond a {slo_ms} ms SLO,\n\
         motivating GPU serving (paper §1)."
    );
}
