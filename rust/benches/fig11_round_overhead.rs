//! Figure 11 (extension) — round-loop control-plane overhead: rounds/sec
//! and inter-round gap for the serial (`pipeline_depth = 1`) vs pipelined
//! (`pipeline_depth = 2`) driver loop at lanes = 1 / 2 / 4.
//!
//! The paper's space-time wins assume the scheduler itself is not the
//! bottleneck; D-STACK (arXiv:2304.13541) and DARIS (arXiv:2504.08795)
//! both show spatio-temporal schedulers only realize their utilization
//! gains when dispatch overhead is amortized across rounds. This bench
//! drives the REAL pipelined machinery this repo serves with — the
//! persistent [`LanePool`] (per-lane SPSC queues, round-tagged
//! completions) under the driver's collect-until-depth discipline — with
//! a deterministic synthetic executor (fixed sleep per launch on the
//! workers, fixed busy-wait planning work on the driver, seed-free
//! workload), so what is measured is exactly the control plane this PR
//! optimizes:
//!
//! * serial (depth 1): plan → dispatch → collect; each round costs
//!   plan_time + execution_time,
//! * pipelined (depth 2): round N executes on the lane workers while the
//!   driver plans round N+1; each round costs ~max(plan, execution).
//!
//! Asserted at the bottom (the ISSUE acceptance claims): at every lane
//! count, depth 2 strictly improves rounds/sec over depth 1 with no
//! SLO-attainment regression; every dispatched launch is collected.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use stgpu::coordinator::lanepool::{LanePool, LaunchExecutor, WorkItem};
use stgpu::coordinator::{InferenceRequest, Launch, LaunchResult, ModelSpec, Priority, ShapeClass};
use stgpu::util::bench::{banner, BenchJson, Table};
use stgpu::util::stats;

const ROUNDS: usize = 250;
/// Per-launch execution time (worker-side sleep, deterministic).
const EXEC_US: u64 = 300;
/// Per-round planning + weight-marshal work on the driver side.
const PLAN_US: u64 = 200;
/// Per-round deadline budget: generous enough that a healthy loop always
/// makes it (attainment compares, it does not saturate the assert).
const SLO_US: u64 = PLAN_US + EXEC_US * 20;

const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 64, n: 64, k: 64 };

/// Deterministic busy-wait for the DRIVER-side planning work (the one
/// thread that is genuinely computing between dispatches).
fn spin(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// Lane-worker executor: sleeps for the launch duration instead of
/// spinning, so lanes=4 runs don't oversubscribe a 2-vCPU CI runner with
/// five spinning threads. Sleep overshoot inflates both depths' rounds
/// identically — serial cadence ≈ plan + exec while pipelined ≈
/// max(plan, exec), so the strict ordering the bench asserts is
/// preserved under scheduler noise.
struct SleepExecutor {
    dur: Duration,
}

impl LaunchExecutor for SleepExecutor {
    fn execute(&self, item: &WorkItem) -> anyhow::Result<LaunchResult> {
        std::thread::sleep(self.dur);
        Ok(LaunchResult {
            outputs: Vec::new(),
            service_s: self.dur.as_secs_f64(),
            marshal_s: 0.0,
            r_bucket: item.launch.r_bucket,
        })
    }
}

fn work_item(round: u64, index: usize, lane: usize, lanes: usize) -> WorkItem {
    let now = Instant::now();
    WorkItem {
        round,
        index,
        lane,
        lanes_resident: lanes,
        launch: Launch {
            class: CLASS,
            entries: vec![InferenceRequest {
                id: round * 100 + index as u64,
                tenant: index,
                class: CLASS,
                payload: vec![],
                arrived: now,
                deadline: now + Duration::from_micros(SLO_US),
                priority: Priority::Normal,
                trace_id: 0,
            }],
            r_bucket: 1,
        },
        spec: ModelSpec::Sgemm { m: 64, n: 64, k: 64 },
        weights: None,
        weights_marshal_s: 0.0,
        cost_hint: 0.0,
        executed_lane: lane,
        stolen: false,
        attempt: 0,
    }
}

struct RunStats {
    depth: usize,
    lanes: usize,
    rounds_per_sec: f64,
    gap_p50_s: f64,
    gap_p99_s: f64,
    attainment: f64,
    collected: u64,
}

struct Ticket {
    round: u64,
    outstanding: usize,
    deadline: Instant,
}

#[derive(Default)]
struct Collector {
    tickets: VecDeque<Ticket>,
    done_at: Vec<Instant>,
    hits: u64,
    misses: u64,
    collected: u64,
}

impl Collector {
    /// Pull ONE completion and account it against its round's ticket —
    /// the single bookkeeping path for both the steady-state loop and the
    /// tail flush.
    fn collect_one(&mut self, pool: &mut LanePool) {
        let c = pool.collect().expect("workers alive");
        self.collected += 1;
        let pos = self
            .tickets
            .iter()
            .position(|t| t.round == c.round)
            .expect("completion matches an in-flight round");
        self.tickets[pos].outstanding -= 1;
        if self.tickets[pos].outstanding == 0 {
            let t = self.tickets.remove(pos).unwrap();
            if c.done <= t.deadline {
                self.hits += 1;
            } else {
                self.misses += 1;
            }
            self.done_at.push(c.done);
        }
    }
}

/// Drive ROUNDS rounds of `lanes` launches each through the pool under
/// the driver's pipeline discipline: dispatch, then collect until at most
/// `depth - 1` rounds remain in flight.
fn run_config(depth: usize, lanes: usize) -> RunStats {
    let exec = Arc::new(SleepExecutor { dur: Duration::from_micros(EXEC_US) });
    let mut pool = LanePool::new(lanes, exec);
    let mut col = Collector::default();
    let t0 = Instant::now();
    for round in 1..=ROUNDS as u64 {
        // The driver-side work a real round does while the previous round
        // executes: drain admission, run the planner, marshal weights.
        spin(Duration::from_micros(PLAN_US));
        let deadline = Instant::now() + Duration::from_micros(SLO_US);
        for lane in 0..lanes {
            pool.dispatch(work_item(round, lane, lane, lanes));
        }
        col.tickets.push_back(Ticket { round, outstanding: lanes, deadline });
        while col.tickets.len() > depth - 1 {
            col.collect_one(&mut pool);
        }
    }
    // Flush the tail so every round is accounted.
    while !col.tickets.is_empty() {
        col.collect_one(&mut pool);
    }
    let makespan = t0.elapsed().as_secs_f64();
    let leftover = pool.shutdown();
    assert!(leftover.is_empty(), "drain must have collected everything");
    col.done_at.sort();
    let gaps: Vec<f64> = col
        .done_at
        .windows(2)
        .map(|w| w[1].duration_since(w[0]).as_secs_f64())
        .collect();
    RunStats {
        depth,
        lanes,
        rounds_per_sec: ROUNDS as f64 / makespan,
        gap_p50_s: stats::percentile(&gaps, 50.0),
        gap_p99_s: stats::percentile(&gaps, 99.0),
        attainment: col.hits as f64 / (col.hits + col.misses).max(1) as f64,
        collected: col.collected,
    }
}

fn main() {
    banner(
        "Figure 11: round-loop overhead — serial vs pipelined persistent-lane driver",
        "pipelining strictly raises rounds/sec at >= equal SLO attainment",
    );
    let mut table = Table::new(&[
        "lanes",
        "depth",
        "rounds_per_sec",
        "gap_p50_us",
        "gap_p99_us",
        "slo_attainment",
        "collected",
    ]);
    let mut results: Vec<RunStats> = Vec::new();
    for &lanes in &[1usize, 2, 4] {
        for &depth in &[1usize, 2] {
            let r = run_config(depth, lanes);
            table.row(&[
                r.lanes.to_string(),
                r.depth.to_string(),
                format!("{:.1}", r.rounds_per_sec),
                format!("{:.1}", r.gap_p50_s * 1e6),
                format!("{:.1}", r.gap_p99_s * 1e6),
                format!("{:.4}", r.attainment),
                r.collected.to_string(),
            ]);
            results.push(r);
        }
    }
    table.emit("fig11_round_overhead");

    for pair in results.chunks(2) {
        let (serial, pipelined) = (&pair[0], &pair[1]);
        assert_eq!(serial.lanes, pipelined.lanes);
        assert_eq!(
            serial.collected, pipelined.collected,
            "both depths must collect every dispatched launch"
        );
        assert!(
            pipelined.rounds_per_sec > serial.rounds_per_sec,
            "lanes={}: depth=2 rounds/sec {:.1} must strictly beat depth=1 {:.1}",
            serial.lanes,
            pipelined.rounds_per_sec,
            serial.rounds_per_sec
        );
        assert!(
            pipelined.attainment >= serial.attainment,
            "lanes={}: attainment {:.4} regressed below serial {:.4}",
            serial.lanes,
            pipelined.attainment,
            serial.attainment
        );
    }
    let s1 = &results[0];
    let p1 = &results[1];
    println!(
        "shape check: lanes=1 serial {:.1} rounds/s vs pipelined {:.1} rounds/s \
         ({:.2}x; ideal {:.2}x = (plan+exec)/max(plan,exec)); p99 inter-round gap \
         {:.1} us -> {:.1} us.",
        s1.rounds_per_sec,
        p1.rounds_per_sec,
        p1.rounds_per_sec / s1.rounds_per_sec,
        (PLAN_US + EXEC_US) as f64 / EXEC_US.max(PLAN_US) as f64,
        s1.gap_p99_s * 1e6,
        p1.gap_p99_s * 1e6,
    );
    BenchJson::new("fig11_round_overhead")
        .throughput(p1.rounds_per_sec)
        .p50_s(p1.gap_p50_s)
        .p99_s(p1.gap_p99_s)
        .slo_attainment(p1.attainment)
        .write();
}
