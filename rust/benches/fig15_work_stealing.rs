//! Figure 15 (extension) — work-conserving lane execution: SLO-met
//! goodput of cost-guided work stealing vs static (private-queue) lanes
//! under a heavy-tailed, mispredicted-duration workload.
//!
//! The setup isolates the failure mode stealing exists for: the planner
//! balances lanes by *predicted* cost, but a deterministic heavy tail
//! (10% of launches run 6-10x their prediction, keyed to the request —
//! data-dependent, so no amount of class-level calibration can see it
//! coming) concentrates real work on whichever lane drew the tail. With
//! private queues the round barrier waits on that lane while its
//! siblings idle; with stealing on, idle lanes take the back of the
//! predicted-longest queue and the round closes near the work-conserving
//! bound. Same trace, same plans' worth of work, same durations — only
//! the execution discipline differs.
//!
//! The bench is self-calibrating so the asserted ratio does not depend
//! on absolute cost-model magnitudes: a closed-loop drain first measures
//! the static (steal-off) service capacity, then the open-loop trace
//! arrives at 1.3x that capacity with an SLO of 30 mean round times.
//! Static lanes saturate (backlog and latency grow without bound, so
//! late arrivals blow the SLO); work-conserving lanes sustain the same
//! offered load. Everything runs on a simulated clock with seeded
//! arrivals and request-keyed tails: the numbers are deterministic.
//!
//! Asserted at the bottom (the ISSUE acceptance claims): steal-on
//! SLO-met goodput >= 1.15x steal-off on the same trace, with no SLO
//! attainment regression; steal-off records zero steals.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use stgpu::coordinator::scheduler::SpaceTimeSched;
use stgpu::coordinator::{QueueSet, RequestContext, Scheduler, ShapeClass};
use stgpu::gpusim::cost::{kernel_service_time, CostCtx};
use stgpu::gpusim::{DeviceSpec, GemmShape, KernelDesc};
use stgpu::util::bench::{banner, BenchJson, Table};
use stgpu::util::prng::Rng;
use stgpu::util::stats;

/// 16 distinct small classes, one tenant each: every saturated round
/// plans ~16 launches across 4 lanes, so a tail launch strands ~3
/// launches' worth of queued work behind it on the unlucky lane.
const CLASSES: [ShapeClass; 16] = [
    ShapeClass { kind: "batched_gemm", m: 128, n: 128, k: 768 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 128, k: 896 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 128, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 128, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 768 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 896 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 768 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 896 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 256, k: 768 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 256, k: 896 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 256, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 256, k: 1152 },
];
const N_TENANTS: usize = CLASSES.len();
const LANES: usize = 4;
const MAX_BATCH: usize = 64;
const SEED: u64 = 1520;
/// Fraction of launches that draw a heavy tail, and its stretch range.
const TAIL_P: f64 = 0.10;
const TAIL_LO: f64 = 6.0;
const TAIL_HI: f64 = 10.0;
/// Offered load relative to the measured static capacity. Far enough
/// above 1.0 that the static run saturates even if the finite
/// calibration drain underestimates true open-loop capacity by a few
/// percent (round makespans are heavy-tailed, so the capacity estimate
/// carries sampling noise), and far enough below the work-conserving
/// uplift that the steal-on run keeps a healthy attainment.
const OVERLOAD: f64 = 1.3;
/// Horizon and SLO in units of the calibrated mean round time.
const HORIZON_ROUNDS: f64 = 400.0;
const SLO_ROUNDS: f64 = 30.0;

fn class_of(tenant: usize) -> ShapeClass {
    CLASSES[tenant.min(N_TENANTS - 1)]
}

/// The heavy tail, keyed to the request (the launch inherits its first
/// entry's draw): a property of the WORK, not of the run, so steal-on
/// and steal-off face the same tailed requests.
fn tail_factor(id: u64) -> f64 {
    let mut r = Rng::new(SEED ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    if r.gen_bool(TAIL_P) {
        r.gen_f64_range(TAIL_LO, TAIL_HI)
    } else {
        1.0
    }
}

/// gpusim ground truth for a fused launch of `r` problems of `class` with
/// `active` lanes concurrently resident — the *predicted* duration; the
/// tail multiplies it into the actual one.
fn predicted(spec: &DeviceSpec, class: ShapeClass, r: usize, active: usize) -> f64 {
    let shape =
        GemmShape::new(class.m.max(1) as u32, class.n.max(1) as u32, class.k.max(1) as u32);
    let mut merged = KernelDesc::sgemm(0, shape);
    let r = r.max(1);
    merged.flops *= r as f64;
    merged.bytes *= r as f64;
    merged.ctas = merged.ctas.saturating_mul(r as u32);
    merged.fused = r as u32;
    let active = active.max(1);
    spec.launch_overhead_s
        + kernel_service_time(
            spec,
            &merged,
            &CostCtx {
                sms: spec.sms as f64 / active as f64,
                concurrency: active as u32,
                static_bw_partition: false,
            },
        )
}

/// Work-conserving (or private-queue) execution of one planned round on a
/// simulated clock — the lane-pool semantics: owners pop the front of
/// their own queue; with `steal` on, a lane that runs dry takes the back
/// of the lane with the largest predicted-remaining backlog (cost-guided
/// victim selection on PREDICTED cost — the thief cannot see the tails
/// either). Returns the round makespan; per-launch completion offsets go
/// to `done_s`.
fn execute_round(
    lane_of: &[usize],
    durs: &[f64],
    preds: &[f64],
    n_lanes: usize,
    steal: bool,
    done_s: &mut Vec<f64>,
    steals: &mut u64,
) -> f64 {
    let n = durs.len();
    done_s.clear();
    done_s.resize(n, 0.0);
    let mut qs: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_lanes];
    let mut rem_pred = vec![0.0f64; n_lanes];
    for i in 0..n {
        qs[lane_of[i]].push_back(i);
        rem_pred[lane_of[i]] += preds[i];
    }
    let mut cursor = vec![0.0f64; n_lanes];
    let mut remaining = n;
    while remaining > 0 {
        // The earliest-free lane that can act: own work first, else (with
        // stealing) anything left anywhere.
        let mut l = usize::MAX;
        for c in 0..n_lanes {
            let can = !qs[c].is_empty()
                || (steal && qs.iter().enumerate().any(|(o, q)| o != c && !q.is_empty()));
            if can && (l == usize::MAX || cursor[c] < cursor[l]) {
                l = c;
            }
        }
        let i = if let Some(i) = qs[l].pop_front() {
            rem_pred[l] -= preds[i];
            i
        } else {
            let mut v = usize::MAX;
            for c in 0..n_lanes {
                if c == l || qs[c].is_empty() {
                    continue;
                }
                if v == usize::MAX || rem_pred[c] > rem_pred[v] {
                    v = c;
                }
            }
            let i = qs[v].pop_back().expect("victim checked nonempty");
            rem_pred[v] -= preds[i];
            *steals += 1;
            i
        };
        cursor[l] += durs[i];
        done_s[i] = cursor[l];
        remaining -= 1;
    }
    cursor.iter().cloned().fold(0.0, f64::max)
}

struct RunResult {
    completed: u64,
    hits: u64,
    misses: u64,
    makespan_s: f64,
    rounds: u64,
    steals: u64,
    latencies: Vec<f64>,
}

impl RunResult {
    fn attainment(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Replay `arrivals` (sorted `(t_arrival, tenant)`) through the real
/// SpaceTimeSched at 4 lanes with tailed ground-truth durations.
fn run(arrivals: &[(f64, usize)], slo_s: f64, steal: bool) -> RunResult {
    let spec = DeviceSpec::v100();
    let base = Instant::now();
    let mut sched = SpaceTimeSched::new(vec![1, 2, 4, 8, 16, 32, 64], MAX_BATCH)
        .spatial_lanes(LANES, None);
    let mut q = QueueSet::new(N_TENANTS, 1 << 16);
    let mut idx = 0usize;
    let mut t = 0.0f64;
    let mut res = RunResult {
        completed: 0,
        hits: 0,
        misses: 0,
        makespan_s: 0.0,
        rounds: 0,
        steals: 0,
        latencies: Vec::with_capacity(arrivals.len()),
    };
    let mut done_s: Vec<f64> = Vec::new();
    loop {
        while idx < arrivals.len() && arrivals[idx].0 <= t {
            let (arr, tenant) = arrivals[idx];
            let arrived = base + Duration::from_secs_f64(arr);
            // Context-carrying API: deadline rides the RequestContext.
            let ctx =
                RequestContext::new(tenant).with_budget(Duration::from_secs_f64(slo_s));
            q.push(ctx.into_request(idx as u64, class_of(tenant), vec![], arrived, Duration::ZERO))
                .expect("bench queues are effectively unbounded");
            idx += 1;
        }
        if q.is_empty() {
            match arrivals.get(idx) {
                Some(&(next, _)) => {
                    t = next; // idle-skip to the next arrival
                    continue;
                }
                None => break,
            }
        }
        let now = base + Duration::from_secs_f64(t);
        let plan = sched.plan_round_at(&mut q, now);
        let n_lanes = plan.n_lanes.max(1);
        let active = plan.lanes_used().max(1);
        let preds: Vec<f64> = plan
            .launches
            .iter()
            .map(|l| predicted(&spec, l.class, l.r_bucket, active))
            .collect();
        let durs: Vec<f64> = plan
            .launches
            .iter()
            .enumerate()
            .map(|(i, l)| preds[i] * l.entries.first().map_or(1.0, |e| tail_factor(e.id)))
            .collect();
        let lane_of: Vec<usize> = (0..plan.launches.len()).map(|i| plan.lane(i)).collect();
        let dt =
            execute_round(&lane_of, &durs, &preds, n_lanes, steal, &mut done_s, &mut res.steals);
        for (i, launch) in plan.launches.iter().enumerate() {
            let done = base + Duration::from_secs_f64(t + done_s[i]);
            for e in &launch.entries {
                res.completed += 1;
                res.latencies.push(done.duration_since(e.arrived).as_secs_f64());
                if done <= e.deadline {
                    res.hits += 1;
                } else {
                    res.misses += 1;
                }
            }
        }
        res.rounds += 1;
        t += dt;
    }
    res.makespan_s = t;
    res.latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    res
}

fn main() {
    banner(
        "Figure 15: work-conserving lane execution (cost-guided stealing, heavy-tailed load)",
        "steal-on SLO-met goodput >= 1.15x steal-off at >= equal attainment on the same trace",
    );

    // Calibration: a closed-loop drain (everything queued at t = 0,
    // steal OFF) measures the static service capacity and mean round
    // time, anchoring the open-loop trace and the SLO to the device's
    // actual speed instead of hard-coded absolutes.
    // 8192 requests -> ~128 saturated rounds: enough samples that the
    // heavy-tailed per-round makespan noise averages out of the capacity
    // estimate (at 2048 / ~32 rounds the estimate can sit low enough
    // that OVERLOAD x cap no longer saturates the static run).
    let cal_n = 8192usize;
    let cal: Vec<(f64, usize)> = (0..cal_n).map(|j| (0.0, j % N_TENANTS)).collect();
    let calib = run(&cal, 1e9, false);
    assert!(calib.makespan_s > 0.0 && calib.rounds > 0);
    let cap_off_rps = calib.completed as f64 / calib.makespan_s;
    let round_s = calib.makespan_s / calib.rounds as f64;
    let rate = OVERLOAD * cap_off_rps;
    let horizon_s = HORIZON_ROUNDS * round_s;
    let slo_s = SLO_ROUNDS * round_s;

    // Open-loop trace at OVERLOAD x static capacity: uniform spacing, tenants
    // round-robin. Deterministic; tails are keyed per request id.
    let mut arrivals: Vec<(f64, usize)> = Vec::new();
    let mut j = 0usize;
    loop {
        let t = (j + 1) as f64 / rate;
        if t >= horizon_s {
            break;
        }
        arrivals.push((t, j % N_TENANTS));
        j += 1;
    }

    let off = run(&arrivals, slo_s, false);
    let on = run(&arrivals, slo_s, true);
    let goodput = |r: &RunResult| r.hits as f64 / horizon_s;

    let mut table = Table::new(&[
        "mode",
        "completed",
        "slo_attainment",
        "goodput_rps",
        "makespan_s",
        "steals",
        "p50_s",
        "p99_s",
    ]);
    for (name, r) in [("steal-off", &off), ("steal-on", &on)] {
        table.row(&[
            name.to_string(),
            r.completed.to_string(),
            format!("{:.4}", r.attainment()),
            format!("{:.1}", goodput(r)),
            format!("{:.4}", r.makespan_s),
            r.steals.to_string(),
            format!("{:.5}", stats::percentile_sorted(&r.latencies, 50.0)),
            format!("{:.5}", stats::percentile_sorted(&r.latencies, 99.0)),
        ]);
    }
    table.emit("fig15_work_stealing");

    assert_eq!(
        off.completed, on.completed,
        "both disciplines must complete the whole trace"
    );
    assert_eq!(off.steals, 0, "steal-off must never steal");
    assert!(on.steals > 0, "the tailed trace must actually provoke steals");
    assert!(
        on.attainment() >= off.attainment(),
        "stealing must not regress attainment: {:.4} vs {:.4}",
        on.attainment(),
        off.attainment()
    );
    let ratio = goodput(&on) / goodput(&off).max(1e-9);
    assert!(
        ratio >= 1.15,
        "steal-on SLO-met goodput must be >= 1.15x steal-off, got {:.3}x \
         ({:.1} vs {:.1} rps)",
        ratio,
        goodput(&on),
        goodput(&off)
    );
    println!(
        "shape check: static capacity {:.1} rps (round {:.1} us); offered {:.1} rps; \
         steal-on goodput {:.1} rps = {:.2}x steal-off {:.1} rps; \
         attainment {:.4} vs {:.4}; {} steals across {} rounds.",
        cap_off_rps,
        round_s * 1e6,
        rate,
        goodput(&on),
        ratio,
        goodput(&off),
        on.attainment(),
        off.attainment(),
        on.steals,
        on.rounds,
    );
    BenchJson::new("fig15_work_stealing")
        .throughput(goodput(&on))
        .slo_attainment(on.attainment())
        .p50_s(stats::percentile_sorted(&on.latencies, 50.0))
        .p99_s(stats::percentile_sorted(&on.latencies, 99.0))
        .scale(LANES as f64)
        .write();
}
