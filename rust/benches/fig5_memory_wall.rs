//! Figure 5 — the 16 GB memory wall.
//!
//! Paper claim: time multiplexing and implicit spatial multiplexing
//! (process-per-replica) exhaust V100 memory at 18 ResNet-50 replicas;
//! explicit CUDA-streams-in-one-process scales to at least 60.
//!
//! Regenerates the figure's series: per-replica memory accounting and the
//! max replica count per deployment shape.

use stgpu::gpusim::memory::{max_replicas, plan, DeploymentShape};
use stgpu::gpusim::DeviceSpec;
use stgpu::models::zoo;
use stgpu::util::bench::{banner, BenchJson, Table};

fn main() {
    banner(
        "Figure 5: replica scaling against the 16 GB memory wall",
        "process-per-replica walls at 18 ResNet-50s; explicit streams reach 60+",
    );
    let spec = DeviceSpec::v100();
    let model = zoo::resnet50();
    let fp = model.footprint(26); // the paper's SLO-max batch

    let mut table = Table::new(&["replicas", "proc_per_replica_GB", "fits", "shared_streams_GB", "fits "]);
    for replicas in [1u32, 4, 8, 12, 16, 17, 18, 19, 24, 32, 48, 60, 64] {
        let p = plan(&spec, DeploymentShape::ProcessPerReplica, &fp, replicas);
        let s = plan(&spec, DeploymentShape::SharedProcessStreams, &fp, replicas);
        let gb = |b: u64| b as f64 / (1u64 << 30) as f64;
        table.row(&[
            replicas.to_string(),
            format!("{:.2}", gb(p.total_bytes)),
            if p.fits { "yes".into() } else { "NO".into() },
            format!("{:.2}", gb(s.total_bytes)),
            if s.fits { "yes".into() } else { "NO".into() },
        ]);
    }
    table.emit("fig5_memory_wall");

    let wall_proc = max_replicas(&spec, DeploymentShape::ProcessPerReplica, &fp);
    let wall_streams = max_replicas(&spec, DeploymentShape::SharedProcessStreams, &fp);
    println!(
        "max ResNet-50 replicas — process-per-replica: {wall_proc} (paper: 18), \
         explicit streams: {wall_streams} (paper: >= 60)"
    );
    // Schema note: throughput carries the explicit-streams replica wall
    // (replicas, not req/s) — the figure's headline scalar.
    BenchJson::new("fig5_memory_wall")
        .throughput(wall_streams as f64)
        .write();
    println!(
        "shape check: contexts+workspaces dominate per-process deployments;\n\
         sharing one context leaves only weights+activations per replica."
    );
}
