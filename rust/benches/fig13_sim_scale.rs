//! Figure 13 (extension) — cluster-scale simulation throughput: simulated
//! events per second of wall clock for the struct-of-arrays gpusim engine
//! at 1 / 16 / 128 devices, against the per-event reference engine at 128.
//!
//! The vectorized engine exists so that offline search (`stgpu tune`), the
//! CI property tests, and cluster-scale what-if studies can afford to
//! replay large simulations: per-tenant state lives in flat parallel
//! arrays, fusion classes are interned up front (no `WorkloadClass`
//! string clone per round), round scratch is pre-sized once, and trace
//! recording is opt-in (a closure that never runs with `--trace` off).
//! The reference engine keeps the original per-event representation and
//! is the bit-for-bit oracle.
//!
//! Three claims, all asserted here:
//! * **Equivalence**: at 128 devices the two engines produce bitwise
//!   identical reports (makespans, counters, rounds) per device.
//! * **Zero hot-path allocation**: every vectorized device report shows
//!   `scratch_grows == 0` (the capacity watchdog saw no post-warmup
//!   growth) and an unallocated trace buffer.
//! * **Throughput**: the vectorized engine simulates >= 10x more
//!   events/sec than the reference engine at 128 devices.
//!
//! Emits `results/BENCH_fig13_sim_scale.json` for the CI bench gate:
//! `throughput` = vectorized events/sec at 128 devices, `p50` =
//! vectorized wall seconds, `p99` = reference-engine wall seconds (both
//! informational in the gate; throughput is the gated trajectory).

use std::time::Instant;

use stgpu::gpusim::{
    run_pool, DeviceSpec, Engine, GemmShape, KernelDesc, Policy, PoolReport, SimConfig,
    TenantWorkload,
};
use stgpu::util::bench::{banner, BenchJson, Table};

/// Per-device shard: half GEMM tenants (fused into super-kernels), half
/// named non-GEMM tenants. The long name is deliberate: the reference
/// engine's `class_key()` clones it per tenant per round, which is
/// exactly the overhead class interning removes.
const TENANTS_PER_DEVICE: usize = 24;
const ITERS: u32 = 300;
const MAX_BATCH: u32 = 16;
const LONG_NAME: &str = "fused_layernorm_gelu_residual_dropout_seq512_h1024";

fn workloads(devices: usize) -> Vec<TenantWorkload> {
    let n = devices * TENANTS_PER_DEVICE;
    let mut w = Vec::with_capacity(n);
    for i in 0..n {
        if i % 2 == 0 {
            w.push(TenantWorkload::new(
                vec![KernelDesc::sgemm(i, GemmShape::RESNET18_CONV2_2)],
                ITERS,
            ));
        } else {
            w.push(TenantWorkload::new(
                vec![KernelDesc::other(i, LONG_NAME, 2.0e8, 6.0e6, 72)],
                ITERS,
            ));
        }
    }
    w
}

struct Run {
    devices: usize,
    engine: Engine,
    wall_s: f64,
    events: u64,
    eps: f64,
    report: PoolReport,
}

fn measure(devices: usize, engine: Engine) -> Run {
    let cfg = SimConfig::new(DeviceSpec::v100(), Policy::SpaceTime { max_batch: MAX_BATCH })
        .with_engine(engine);
    let w = workloads(devices);
    let t0 = Instant::now();
    let report = run_pool(&cfg, &w, devices);
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    // One "event" = one simulated kernel launch or one completed
    // inference — the units both engines process one at a time.
    let events = report.kernel_launches() + report.total_completed();
    Run {
        devices,
        engine,
        wall_s,
        events,
        eps: events as f64 / wall_s,
        report,
    }
}

fn main() {
    banner(
        "Figure 13: cluster-scale simulation throughput (events/sec)",
        "vectorized engine >= 10x reference events/sec at 128 devices, bit-for-bit equal",
    );
    // Warm caches/allocator so the first measured run is not cold.
    let _ = measure(16, Engine::Vectorized);

    let runs = vec![
        measure(1, Engine::Vectorized),
        measure(16, Engine::Vectorized),
        measure(128, Engine::Vectorized),
        measure(128, Engine::Legacy),
    ];

    let mut table = Table::new(&["engine", "devices", "events", "wall", "events/sec"]);
    for r in &runs {
        table.row(&[
            r.engine.label().to_string(),
            r.devices.to_string(),
            r.events.to_string(),
            format!("{:.4}s", r.wall_s),
            format!("{:.3e}", r.eps),
        ]);
    }
    table.emit("fig13_sim_scale");

    let vec128 = &runs[2];
    let legacy128 = &runs[3];

    // Equivalence: the vectorized engine is a drop-in replacement — at
    // 128 devices every per-device report is bitwise identical.
    assert_eq!(vec128.events, legacy128.events, "engines disagree on event count");
    assert_eq!(
        vec128.report.assignment, legacy128.report.assignment,
        "engines must shard tenants identically"
    );
    for (d, (v, l)) in vec128
        .report
        .per_device
        .iter()
        .zip(&legacy128.report.per_device)
        .enumerate()
    {
        assert_eq!(
            v.makespan.to_bits(),
            l.makespan.to_bits(),
            "device {d}: makespan diverged"
        );
        assert_eq!(v.kernel_launches, l.kernel_launches, "device {d}");
        assert_eq!(v.superkernel_launches, l.superkernel_launches, "device {d}");
        assert_eq!(v.fused_problems, l.fused_problems, "device {d}");
        assert_eq!(v.rounds, l.rounds, "device {d}");
        assert_eq!(v.total_completed(), l.total_completed(), "device {d}");
    }

    // Zero per-event allocation: scratch never grew after warmup and the
    // disabled trace never allocated, on every vectorized run.
    for r in &runs[..3] {
        let grows: u64 = r.report.per_device.iter().map(|d| d.scratch_grows).sum();
        assert_eq!(
            grows, 0,
            "{} devices: vectorized scratch grew {grows} times post-warmup",
            r.devices
        );
        for (d, rep) in r.report.per_device.iter().enumerate() {
            assert_eq!(
                rep.trace.events.capacity(),
                0,
                "{} devices: device {d} allocated a trace with tracing off",
                r.devices
            );
        }
    }

    // Scale sanity: event volume grows with the pool (same per-device
    // shard replicated), and every simulated inference completed.
    assert_eq!(vec128.report.total_completed(), (128 * TENANTS_PER_DEVICE) as u64 * ITERS as u64);
    assert!(runs[0].events < runs[1].events && runs[1].events < runs[2].events);

    // The headline: >= 10x the reference engine's events/sec at 128
    // devices (ISSUE 7 acceptance floor).
    let speedup = vec128.eps / legacy128.eps.max(1e-9);
    println!(
        "shape check: vectorized {:.3e} events/s vs reference {:.3e} events/s \
         at 128 devices -> {speedup:.1}x (floor 10x); {} events bit-for-bit equal.",
        vec128.eps, legacy128.eps, vec128.events
    );
    assert!(
        speedup >= 10.0,
        "vectorized engine only {speedup:.1}x the reference events/sec (need >= 10x)"
    );

    BenchJson::new("fig13_sim_scale")
        .throughput(vec128.eps)
        .p50_s(vec128.wall_s)
        .p99_s(legacy128.wall_s)
        .scale(128.0)
        .write();
}
