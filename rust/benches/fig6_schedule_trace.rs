//! Figure 6 — the schedule illustration: R SGEMMs under time-only,
//! space-only and space-time multiplexing.
//!
//! Paper claim (illustrative): time multiplexing serializes R kernel
//! invocations; spatial multiplexing overlaps them on partitioned
//! resources; space-time merges them into one super-kernel invocation that
//! fills the device ("outer boxes depict a single CUDA kernel invocation").
//!
//! Regenerates the figure as ASCII Gantt charts + launch/occupancy counts
//! from the simulator's trace capture.

use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::util::bench::{banner, fmt_secs, BenchJson, Table};
use stgpu::workload::sgemm_tenants;

fn main() {
    banner(
        "Figure 6: R SGEMMs scheduled by each multiplexing method",
        "space-time reduces kernel invocations via inter-model batching",
    );
    let spec = DeviceSpec::v100();
    let shape = GemmShape::RESNET18_CONV2_2;
    let r = 4; // the figure draws R=4 problems

    let mut table = Table::new(&["policy", "launches", "makespan", "occupancy_%"]);
    let mut makespans = Vec::new();
    for policy in [
        Policy::TimeMux,
        Policy::SpaceMuxStreams,
        Policy::SpaceTime { max_batch: 64 },
    ] {
        let label = policy.label();
        let cfg = SimConfig::new(spec.clone(), policy).with_trace();
        let report = gpusim::run(&cfg, &sgemm_tenants(r, 1, shape));
        println!("--- {label} ---");
        println!("{}", report.trace.render_gantt(72));
        makespans.push(report.trace.makespan());
        table.row(&[
            label.to_string(),
            report.trace.launches().to_string(),
            fmt_secs(report.trace.makespan()),
            format!("{:.0}", report.trace.occupancy(spec.sms as f64) * 100.0),
        ]);
    }
    table.emit("fig6_schedule_trace");
    // p50/p99 over the three policy makespans (best vs worst policy).
    BenchJson::new("fig6_schedule_trace")
        .p50_s(stgpu::util::stats::percentile(&makespans, 50.0))
        .p99_s(stgpu::util::stats::percentile(&makespans, 99.0))
        .write();
    println!(
        "shape check: time-mux = {r} serialized launches; streams = {r} \
         overlapped launches on partitioned SMs; space-time = ONE launch \
         covering all {r} problems at full occupancy."
    );
}
