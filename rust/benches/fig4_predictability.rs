//! Figure 4 — latency predictability under MPS spatial sharing.
//!
//! Paper claim: across tenants under MPS there is up to a 25% latency gap
//! between the fastest and slowest model on the GPU, and the anomaly is
//! exacerbated with an ODD number of concurrent processes.
//!
//! Regenerates the figure's series: per-tenant mean latency spread
//! (fastest vs straggler) for 2..15 tenants, even vs odd, plus the same
//! run with the space-time scheduler + eviction showing the gap closing.

use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::util::bench::{banner, BenchJson, Table};
use stgpu::workload::sgemm_tenants;

fn main() {
    banner(
        "Figure 4: fastest-vs-straggler latency gap under MPS",
        "up to 25% gap; worse for odd tenant counts",
    );
    let spec = DeviceSpec::v100();
    let shape = GemmShape::RESNET18_CONV2_2;
    let iters = 24;

    let mut table = Table::new(&["tenants", "parity", "mps_gap_%", "streams_gap_%", "space_time_gap_%"]);
    let mut worst_even: f64 = 0.0;
    let mut worst_odd: f64 = 0.0;
    for n in 2..=15usize {
        let gap = |policy: Policy| {
            let cfg = SimConfig::new(spec.clone(), policy);
            gpusim::run(&cfg, &sgemm_tenants(n, iters, shape)).straggler_gap() * 100.0
        };
        let mps = gap(Policy::SpaceMuxMps { anomaly_seed: 7 });
        let streams = gap(Policy::SpaceMuxStreams);
        let st = gap(Policy::SpaceTime { max_batch: 64 });
        if n % 2 == 0 {
            worst_even = worst_even.max(mps);
        } else {
            worst_odd = worst_odd.max(mps);
        }
        table.row(&[
            n.to_string(),
            if n % 2 == 0 { "even".into() } else { "odd".into() },
            format!("{mps:.1}"),
            format!("{streams:.1}"),
            format!("{st:.1}"),
        ]);
    }
    table.emit("fig4_predictability");
    // Schema note (README "Performance"): fig4 has no latency axis —
    // p99 carries the worst MPS straggler gap as a fraction.
    BenchJson::new("fig4_predictability")
        .p99_s(worst_even.max(worst_odd) / 100.0)
        .write();
    println!(
        "worst MPS gap — even tenants: {worst_even:.1}% | odd tenants: {worst_odd:.1}% \
         (paper: up to 25%, odd worse)"
    );
    println!(
        "shape check: space-time keeps the gap near zero — one super-kernel\n\
         gives every fused problem the same service time (isolation restored)."
    );
}
