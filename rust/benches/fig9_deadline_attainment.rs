//! Figure 9 (extension) — SLO-attainment ratio under a bursty arrival
//! trace: deadline-aware (EDF) SpaceTime vs FIFO SpaceTime vs TimeMux.
//!
//! The paper's headline is not just utilization but **predictability**;
//! related work makes the deadline the scheduling primitive (predictable-
//! latency planning, arXiv:2512.18725; DARIS deadline-ordered admission,
//! arXiv:2504.08795). This bench replays one bursty multi-tenant trace
//! (`workload::arrivals`, tight- and loose-SLO tenants mixed on one shape
//! class) through the three policies on a simulated clock, with launch
//! durations taken from the same roofline cost model the EDF planner
//! plans against:
//!
//! * **EDF SpaceTime** — earliest-deadline drain + cost-model-planned
//!   launches (splitting when a fused launch would blow a deadline).
//! * **FIFO SpaceTime** — the classic fair round-robin drain.
//! * **TimeMux** — one problem per launch, no fusion.
//!
//! Expected shape: when bursts push the backlog past one round's fusion
//! cap, FIFO hands the tight-SLO tenants only a fair share of the launch
//! lanes and their requests miss; EDF gives urgent requests every lane
//! they need at the same aggregate throughput (same work, same fused
//! launches, different order). Asserted at the bottom: EDF attainment
//! strictly above FIFO at >= 97% of FIFO throughput.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use stgpu::config::SchedulerKind;
use stgpu::coordinator::batcher::PaddingPolicy;
use stgpu::coordinator::scheduler::{
    make_scheduler, make_scheduler_deadline_aware, Scheduler,
};
use stgpu::coordinator::{CostModel, QueueSet, RequestContext, ShapeClass};
use stgpu::util::bench::{banner, BenchJson, Table};
use stgpu::workload::arrivals::{ArrivalProcess, RequestTrace};

const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 1024, n: 1024, k: 1024 };
const N_TENANTS: usize = 8;
/// Tenants 0..4 are latency-critical, 4..8 are throughput-oriented.
const TIGHT_SLO_S: f64 = 0.008;
const LOOSE_SLO_S: f64 = 0.200;
const MAX_BATCH: usize = 16;
const HORIZON_S: f64 = 2.0;
const SEED: u64 = 42;

fn slo_of(tenant: usize) -> f64 {
    if tenant < N_TENANTS / 2 {
        TIGHT_SLO_S
    } else {
        LOOSE_SLO_S
    }
}

fn buckets() -> Vec<usize> {
    vec![1, 2, 4, 8, 16, 32, 64]
}

fn trace() -> RequestTrace {
    // Bursty arrivals slightly above the fused-service capacity on
    // average: backlog episodes build during high phases and drain in the
    // low ones — exactly the regime where drain ORDER decides attainment.
    let processes: Vec<(usize, ArrivalProcess)> = (0..N_TENANTS)
        .map(|t| {
            (t, ArrivalProcess::Bursty { low: 150.0, high: 1200.0, dwell: 0.1 })
        })
        .collect();
    RequestTrace::generate(&processes, SEED, HORIZON_S)
}

struct PolicyResult {
    completed: u64,
    hits: u64,
    misses: u64,
    tight_hits: u64,
    tight_total: u64,
    makespan_s: f64,
    launches: u64,
    splits: u64,
}

impl PolicyResult {
    fn attainment(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    fn tight_attainment(&self) -> f64 {
        if self.tight_total == 0 {
            1.0
        } else {
            self.tight_hits as f64 / self.tight_total as f64
        }
    }

    fn throughput_rps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.makespan_s
        }
    }
}

/// Replay the trace through one scheduler on a simulated clock. Launch
/// durations are the cost model's analytic roofline times — the same
/// ground truth the EDF planner predicts against (and is fed back as
/// measurements, closing its calibration loop with zero error).
fn run_policy(mut sched: Box<dyn Scheduler>, cost: &Arc<Mutex<CostModel>>) -> PolicyResult {
    let tr = trace();
    let base = Instant::now();
    let mut q = QueueSet::new(N_TENANTS, 1 << 16);
    let mut idx = 0usize;
    let mut t = 0.0f64; // simulated seconds since base
    let mut res = PolicyResult {
        completed: 0,
        hits: 0,
        misses: 0,
        tight_hits: 0,
        tight_total: 0,
        makespan_s: 0.0,
        launches: 0,
        splits: 0,
    };
    loop {
        // Admit everything that has arrived by the simulated clock.
        while idx < tr.requests.len() && tr.requests[idx].t_arrival <= t {
            let r = tr.requests[idx];
            let arrived = base + Duration::from_secs_f64(r.t_arrival);
            // Context-carrying API: the wire deadline (tenant SLO as a
            // budget) rides the RequestContext into the EDF heap.
            let ctx = RequestContext::new(r.tenant)
                .with_budget(Duration::from_secs_f64(slo_of(r.tenant)));
            q.push(ctx.into_request(idx as u64, CLASS, vec![], arrived, Duration::ZERO))
                .expect("bench queues are effectively unbounded");
            idx += 1;
        }
        if q.is_empty() {
            match tr.requests.get(idx) {
                Some(next) => {
                    t = next.t_arrival; // idle-skip to the next arrival
                    continue;
                }
                None => break, // trace exhausted and drained
            }
        }
        let now = base + Duration::from_secs_f64(t);
        let plan = sched.plan_round_at(&mut q, now);
        res.splits += plan.deadline_splits as u64;
        for launch in &plan.launches {
            let dur = {
                let mut cm = cost.lock().unwrap();
                let d = cm.analytic_seed(launch.class, launch.r_bucket);
                cm.observe(launch.class, launch.r_bucket, d);
                d
            };
            t += dur;
            res.launches += 1;
            let done = base + Duration::from_secs_f64(t);
            for e in &launch.entries {
                let met = done <= e.deadline;
                res.completed += 1;
                if met {
                    res.hits += 1;
                } else {
                    res.misses += 1;
                }
                if slo_of(e.tenant) == TIGHT_SLO_S {
                    res.tight_total += 1;
                    if met {
                        res.tight_hits += 1;
                    }
                }
            }
        }
    }
    res.makespan_s = t;
    res
}

fn main() {
    banner(
        "Figure 9: SLO attainment under bursty load (EDF vs FIFO vs TimeMux)",
        "deadline-aware space-time strictly improves attainment at equal throughput",
    );
    let shared = || Arc::new(Mutex::new(CostModel::new()));

    let edf_cost = shared();
    let edf = run_policy(
        make_scheduler_deadline_aware(
            SchedulerKind::SpaceTime,
            buckets(),
            MAX_BATCH,
            PaddingPolicy::PadToBucket,
            edf_cost.clone(),
            0.0,
        ),
        &edf_cost,
    );
    let fifo_cost = shared();
    let fifo = run_policy(
        make_scheduler(SchedulerKind::SpaceTime, buckets(), MAX_BATCH),
        &fifo_cost,
    );
    let tm_cost = shared();
    let timemux = run_policy(
        make_scheduler(SchedulerKind::TimeMux, buckets(), MAX_BATCH),
        &tm_cost,
    );

    let mut table = Table::new(&[
        "policy",
        "completed",
        "slo_attainment",
        "tight_attainment",
        "throughput_rps",
        "makespan_s",
        "launches",
        "splits",
    ]);
    for (name, r) in [
        ("edf-space-time", &edf),
        ("fifo-space-time", &fifo),
        ("time-mux", &timemux),
    ] {
        table.row(&[
            name.to_string(),
            r.completed.to_string(),
            format!("{:.4}", r.attainment()),
            format!("{:.4}", r.tight_attainment()),
            format!("{:.1}", r.throughput_rps()),
            format!("{:.3}", r.makespan_s),
            r.launches.to_string(),
            r.splits.to_string(),
        ]);
    }
    table.emit("fig9_deadline_attainment");
    println!(
        "calibration: EDF predictor relative error {:.4} after {} observed launches",
        edf_cost.lock().unwrap().calibration_error(),
        edf_cost.lock().unwrap().observations(),
    );

    // The acceptance claims, asserted so regressions fail loudly.
    assert_eq!(
        edf.completed, fifo.completed,
        "both space-time variants must complete the whole trace"
    );
    assert!(
        edf.attainment() > fifo.attainment(),
        "EDF must strictly improve SLO attainment: {:.4} vs {:.4}",
        edf.attainment(),
        fifo.attainment()
    );
    assert!(
        edf.tight_attainment() > fifo.tight_attainment(),
        "the win must come from the latency-critical tenants: {:.4} vs {:.4}",
        edf.tight_attainment(),
        fifo.tight_attainment()
    );
    assert!(
        edf.throughput_rps() >= 0.97 * fifo.throughput_rps(),
        "EDF must not trade meaningful throughput: {:.1} vs {:.1} req/s",
        edf.throughput_rps(),
        fifo.throughput_rps()
    );
    assert!(
        edf.attainment() > timemux.attainment(),
        "fusion + EDF must dominate unfused time multiplexing"
    );
    println!(
        "shape check: EDF attainment {:.4} > FIFO {:.4} > feasible-throughput \
         floor; EDF throughput {:.1} req/s vs FIFO {:.1} (ratio {:.3}); \
         time-mux collapses to {:.4} attainment at {:.1} req/s.",
        edf.attainment(),
        fifo.attainment(),
        edf.throughput_rps(),
        fifo.throughput_rps(),
        edf.throughput_rps() / fifo.throughput_rps().max(1e-9),
        timemux.attainment(),
        timemux.throughput_rps(),
    );
    BenchJson::new("fig9_deadline_attainment")
        .throughput(edf.throughput_rps())
        .slo_attainment(edf.attainment())
        .write();
}
