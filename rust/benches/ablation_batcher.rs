//! Ablation — dynamic-batcher design choices (DESIGN.md §5).
//!
//! Three knobs the paper's §4 design leaves open, measured on the REAL
//! PJRT path:
//!  1. R-bucket granularity: powers-of-two vs exact-R executables vs one
//!     giant bucket — padding waste vs executable-cache size.
//!  2. Fusion (weight) cache on/off: marshal bytes per launch.
//!  3. max_batch cap: fused-R vs latency.
//!
//! Requires `make artifacts`.

use std::time::Instant;

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::{Coordinator, DynamicBatcher, PaddingPolicy};
use stgpu::coordinator::request::{InferenceRequest, Priority, ShapeClass};
use stgpu::util::bench::{banner, fmt_secs, Table};
use stgpu::util::prng::Rng;

fn main() {
    banner(
        "Ablation: dynamic batcher design choices",
        "bucket granularity / fusion cache / max_batch trade-offs",
    );
    bucket_granularity();
    fusion_cache_effect();
    max_batch_sweep();
}

/// Padding waste, launch count and executable count per bucketing policy.
fn bucket_granularity() {
    println!("--- R-bucket granularity (padding waste vs cache size vs launches) ---");
    let policies: [(&str, Vec<usize>, PaddingPolicy); 4] = [
        ("pow2 + pad (paper)", vec![1, 2, 4, 8, 16, 32, 64], PaddingPolicy::PadToBucket),
        ("pow2 + split-exact", vec![1, 2, 4, 8, 16, 32, 64], PaddingPolicy::SplitExact),
        ("exact-R", (1..=64).collect(), PaddingPolicy::PadToBucket),
        ("one bucket", vec![64], PaddingPolicy::PadToBucket),
    ];
    let mut table = Table::new(&["policy", "executables", "padding_waste_%", "mean_fused_R"]);
    for (name, buckets, policy) in policies {
        let n_exe = buckets.len();
        let mut b = DynamicBatcher::with_policy(buckets, 64, policy);
        // Realistic arrival mix: bursts of 1..24 same-class problems.
        let mut rng = Rng::new(42);
        let class = ShapeClass::batched_gemm(256, 128, 1152);
        let mut id = 0u64;
        for _ in 0..500 {
            let burst = 1 + rng.gen_range(24) as usize;
            let reqs: Vec<InferenceRequest> = (0..burst)
                .map(|_| {
                    id += 1;
                    InferenceRequest {
                        id,
                        tenant: (id % 8) as usize,
                        class,
                        payload: vec![],
                        arrived: Instant::now(),
                        deadline: Instant::now(),
                        priority: Priority::Normal,
                        trace_id: 0,
                    }
                })
                .collect();
            b.plan(reqs);
        }
        table.row(&[
            name.to_string(),
            n_exe.to_string(),
            format!("{:.1}", b.stats.padding_waste() * 100.0),
            format!("{:.1}", b.stats.mean_fused()),
        ]);
    }
    table.emit("ablation_buckets");
    println!(
        "trade-off: exact-R kills padding but needs 64 compiled executables;\n\
         pow2+pad bounds waste (<50%, typically ~15%) with 7; pow2+split\n\
         gets zero padding from the same 7 at the cost of more launches\n\
         (smaller mean fused R) — right on serial substrates.\n"
    );
}

/// Serving throughput with the weight-stack fusion cache vs without
/// (approximated by clearing it every round via tiny capacity).
fn fusion_cache_effect() {
    println!("--- fusion (weight) cache effect on the real serving path ---");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/ not built\n");
        return;
    }
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        artifacts_dir: dir.into(),
        tenants: (0..8)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: "mlp".into(),
                batch: 1,
                slo_ms: 1000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    };
    let mut table = Table::new(&["fusion_cache", "requests/s", "mean_service", "hit_rate_%"]);
    // Steady-state: same 8 tenants every round -> the lane assignment
    // recurs -> cache hits after round one.
    let mut coord = Coordinator::new(&cfg).unwrap();
    coord.warmup().unwrap();
    let mut rng = Rng::new(3);
    let rounds = 40;
    let t0 = Instant::now();
    let mut service = 0.0;
    let mut served = 0usize;
    for _ in 0..rounds {
        for t in 0..8 {
            let p = coord.random_payload(t, &mut rng);
            coord.submit(t, p).unwrap();
        }
        for r in coord.run_until_drained().unwrap() {
            service += r.service_s;
            served += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let stats = coord.fusion_cache_stats();
    table.row(&[
        "ON (default)".into(),
        format!("{:.0}", served as f64 / dt),
        fmt_secs(service / served as f64),
        format!("{:.0}", stats.hit_rate() * 100.0),
    ]);
    // OFF: capacity-1 cache + two alternating tenant subsets per round —
    // the key alternates, so every launch misses and re-uploads weights.
    let mut coord = Coordinator::new(&cfg).unwrap();
    coord.warmup().unwrap();
    coord.set_fusion_cache_capacity(1);
    let t0 = Instant::now();
    let mut service = 0.0;
    let mut served = 0usize;
    for round in 0..rounds {
        let subset: Vec<usize> = if round % 2 == 0 {
            (0..4).collect()
        } else {
            (4..8).collect()
        };
        for &t in &subset {
            let p = coord.random_payload(t, &mut rng);
            coord.submit(t, p).unwrap();
        }
        for r in coord.run_until_drained().unwrap() {
            service += r.service_s;
            served += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let stats = coord.fusion_cache_stats();
    table.row(&[
        "cold (cap=1, alternating sets)".into(),
        format!("{:.0}", served as f64 / dt),
        fmt_secs(service / served as f64),
        format!("{:.0}", stats.hit_rate() * 100.0),
    ]);
    table.emit("ablation_fusion_cache");
    println!(
        "the paper's observation made measurable: \"overheads gradually\n\
         decrease if we cache super-kernels as workloads stabilize\".\n"
    );
}

/// max_batch sweep on the real path: throughput vs per-request latency.
/// Uses the dispatch-bound matvec shape (512×1×512) where fusion pays on
/// any hardware; for ms-scale kernels on this 1-core host fusion cannot
/// win (see fig7's real-path conv2_2 section — pure Amdahl).
fn max_batch_sweep() {
    println!("--- max_batch cap sweep (real path, 8 matvec sgemm tenants) ---");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/ not built");
        return;
    }
    let mut table = Table::new(&["max_batch", "requests/s", "mean_latency", "mean_fused_R"]);
    for max_batch in [1u32, 4, 16, 64] {
        let cfg = ServerConfig {
            scheduler: SchedulerKind::SpaceTime,
            max_batch,
            artifacts_dir: dir.into(),
            tenants: (0..8)
                .map(|i| TenantConfig {
                    name: format!("t{i}"),
                    model: "sgemm:512x1x512".into(),
                    batch: 1,
                    slo_ms: 1000.0,
                    weight_seed: i as u64,
                })
                .collect(),
            ..Default::default()
        };
        let mut coord = Coordinator::new(&cfg).unwrap();
        coord.warmup().unwrap();
        let mut rng = Rng::new(9);
        let t0 = Instant::now();
        let mut latency = 0.0;
        let mut served = 0usize;
        for _ in 0..10 {
            for t in 0..8 {
                let p = coord.random_payload(t, &mut rng);
                coord.submit(t, p).unwrap();
            }
            for r in coord.run_until_drained().unwrap() {
                latency += r.latency_s;
                served += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let bs = coord.batcher_stats().unwrap();
        table.row(&[
            max_batch.to_string(),
            format!("{:.0}", served as f64 / dt),
            fmt_secs(latency / served as f64),
            format!("{:.1}", bs.mean_fused()),
        ]);
    }
    table.emit("ablation_max_batch");
    println!(
        "measured truth on this substrate: raw-sgemm requests carry their\n\
         whole operands as payload, so per-request host->device upload\n\
         dominates and fusing is neutral-to-negative on 1 core (cap=1\n\
         degenerates to space-mux and wins). The amortization benefit\n\
         appears exactly where the paper puts it: operands resident on\n\
         device — pre-staged (fig7 real-path: 5.9x) or weight-cached\n\
         (fusion-cache ablation above: ~3x)."
    );
}
