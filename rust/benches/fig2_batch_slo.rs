//! Figure 2 — small batch sizes are forced by latency SLOs and leave the
//! GPU underutilized.
//!
//! Paper claim: the largest ResNet-50 batch on a V100 within the SLO is 26,
//! achieving only ~28 % of peak FP32 throughput on average.
//!
//! Regenerates the figure's series: batch size vs latency + achieved
//! fraction of peak, with the SLO line and the max-feasible batch marked.

use stgpu::gpusim::{self, DeviceSpec, Policy, SimConfig};
use stgpu::models::zoo;
use stgpu::util::bench::{banner, fmt_secs, BenchJson, Table};
use stgpu::util::stats;
use stgpu::workload::model_tenants;

fn main() {
    banner(
        "Figure 2: ResNet-50 batch size vs latency vs utilization (V100)",
        "largest batch within SLO = 26, at ~28% of peak FP32",
    );
    let spec = DeviceSpec::v100();
    let peak = spec.peak_flops();
    // The simulator models kernel time only (no framework / cuDNN-descriptor
    // overhead), so its absolute ResNet-50 latencies run ~2.4x below the
    // paper's measured stack. The SLO line is scaled by the same factor so
    // the *operating point* (which batch the SLO admits, and the utilization
    // there) is comparable — see EXPERIMENTS.md "Fig 2" for the derivation.
    let slo_s = 0.100 / 2.33;
    let model = zoo::resnet50();

    let mut table = Table::new(&["batch", "latency", "peak_frac", "within_slo"]);
    let mut max_within = 0u32;
    let mut frac_at_max = 0.0;
    let batches: Vec<u32> = (0..=6).map(|p| 1u32 << p).chain([26, 48].iter().copied()).collect();
    let mut batches = batches;
    batches.sort_unstable();
    batches.dedup();
    let mut lats = Vec::new();
    for batch in batches {
        let cfg = SimConfig::new(spec.clone(), Policy::Exclusive);
        let report = gpusim::run(&cfg, &model_tenants(1, 3, &model, batch));
        let lat = report.mean_latency();
        lats.push(lat);
        let frac = report.throughput_flops() / peak;
        let within = lat <= slo_s;
        if within && batch > max_within {
            max_within = batch;
            frac_at_max = frac;
        }
        table.row(&[
            batch.to_string(),
            fmt_secs(lat),
            format!("{:.1}%", frac * 100.0),
            if within { "yes".into() } else { "NO".into() },
        ]);
    }
    table.emit("fig2_batch_slo");
    BenchJson::new("fig2_batch_slo")
        .throughput(frac_at_max * peak)
        .p50_s(stats::percentile(&lats, 50.0))
        .p99_s(stats::percentile(&lats, 99.0))
        .write();
    println!(
        "largest batch within the {:.1} ms (scaled) SLO: {} at {:.1}% of peak \
         (paper: 26 at ~28%)",
        slo_s * 1e3,
        max_within,
        frac_at_max * 100.0
    );
    println!(
        "shape check: utilization climbs with batch but the SLO caps the\n\
         feasible batch far below saturation — the gap multi-tenancy must fill."
    );
}
