//! §Perf — hot-path microbenchmarks for the L3 coordinator.
//!
//! Targets (DESIGN.md §8): batcher + scheduler decision ≤ 10 µs/request at
//! 10 k req/s; no steady-state compile; fusion-cache hit path avoids weight
//! marshal. Run before/after each optimization; results land in
//! EXPERIMENTS.md §Perf.

use std::sync::Arc;
use std::time::Instant;

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::protocol::{ItemRunner, LaneProtocol, LaneTagged, ProtoPayload, StdEnv};
use stgpu::coordinator::request::{InferenceRequest, Priority, ShapeClass};
use stgpu::coordinator::{make_scheduler, Coordinator, QueueSet};
use stgpu::runtime::HostTensor;
use stgpu::util::bench::{banner, fmt_secs, Bencher, Table};
use stgpu::util::prng::Rng;

fn main() {
    banner(
        "§Perf: L3 hot-path microbenchmarks",
        "schedule decision <= 10 us/request; zero steady-state compiles",
    );
    scheduling_decision();
    steal_path();
    marshal_path();
    end_to_end_components();
}

/// Pure scheduling cost: enqueue + plan_round for a full batch, no PJRT.
fn scheduling_decision() {
    println!("--- scheduling decision cost (no execution) ---");
    let class = ShapeClass::batched_gemm(256, 128, 1152);
    let bench = Bencher::new(10, 50);
    let mut table = Table::new(&["scheduler", "requests", "per_request"]);
    for kind in [
        SchedulerKind::SpaceTime,
        SchedulerKind::TimeMux,
        SchedulerKind::SpaceMux,
        SchedulerKind::Exclusive,
    ] {
        let n_req = 1024usize;
        let mut sched = make_scheduler(kind, vec![1, 2, 4, 8, 16, 32, 64], 64);
        let summary = bench.summarize(|| {
            let mut q = QueueSet::new(16, 10_000);
            for i in 0..n_req {
                q.push(InferenceRequest {
                    id: i as u64,
                    tenant: i % 16,
                    class,
                    payload: vec![],
                    arrived: Instant::now(),
                    deadline: Instant::now(),
                    priority: Priority::Normal,
                    trace_id: 0,
                })
                .unwrap();
            }
            while !q.is_empty() {
                let plan = sched.plan_round(&mut q);
                std::hint::black_box(&plan);
            }
        });
        table.row(&[
            format!("{kind:?}"),
            n_req.to_string(),
            fmt_secs(summary.mean / n_req as f64),
        ]);
    }
    table.emit("perf_sched_decision");
}

/// Work-stealing dispatch/collect cost through the real lane protocol,
/// plus the allocation discipline the driver relies on: once the deques
/// reach steady-state capacity, the steal path must not grow them (no
/// hot-path allocation), even under maximal steal pressure (every item
/// planned onto one lane, three thieves draining it).
fn steal_path() {
    println!("--- lane-pool steal path (skewed dispatch, 4 lanes, no execution) ---");

    struct Item {
        id: u64,
        lane: usize,
        spin: u32,
    }
    impl ProtoPayload for Item {}
    impl LaneTagged for Item {
        fn lane(&self) -> usize {
            self.lane
        }
        fn set_lane(&mut self, lane: usize) {
            self.lane = lane;
        }
    }
    struct Done;
    impl ProtoPayload for Done {}
    struct Spin;
    impl ItemRunner<Item, Done> for Spin {
        fn run(&self, item: Item) -> Done {
            // A tiny compute so the owner lane stays busy long enough for
            // idle lanes to actually steal.
            let mut acc = item.id;
            for x in 0..item.spin {
                acc = acc.wrapping_mul(0x9E37_79B9).wrapping_add(x as u64);
            }
            std::hint::black_box(acc);
            Done
        }
    }

    const LANES: usize = 4;
    const ROUND: usize = 64;
    let mut pool: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(LANES, Arc::new(Spin));
    pool.set_steal(true);
    let mut next_id = 0u64;
    let mut one_round = |pool: &mut LaneProtocol<StdEnv, Item, Done>| {
        for _ in 0..ROUND {
            // Worst case for work conservation: everything planned on lane 0.
            pool.dispatch(Item { id: next_id, lane: 0, spin: 64 });
            next_id += 1;
        }
        for _ in 0..ROUND {
            let d = pool.collect().expect("lane workers alive");
            std::hint::black_box(&d);
        }
    };

    // Warmup until the deques and channels reach steady-state capacity.
    for _ in 0..8 {
        one_round(&mut pool);
    }
    let grows_warm = pool.queue_grows();
    let steals_warm = pool.steals_total();

    let bench = Bencher::new(5, 30);
    let summary = bench.summarize(|| one_round(&mut pool));

    let grows = pool.queue_grows() - grows_warm;
    let steals = pool.steals_total() - steals_warm;
    let mut table = Table::new(&["metric", "value"]);
    table.row(&["per-item dispatch+collect".into(), fmt_secs(summary.mean / ROUND as f64)]);
    table.row(&["steals (measured window)".into(), steals.to_string()]);
    table.row(&["deque growths post-warmup".into(), grows.to_string()]);
    table.emit("perf_steal_path");

    assert!(steals > 0, "skewed dispatch across {LANES} lanes must provoke steals");
    assert_eq!(grows, 0, "steal path must be allocation-free post-warmup (deques grew {grows}x)");
    let leftover = pool.shutdown_drain();
    assert!(leftover.is_empty(), "all dispatched work was collected");
}

/// Gather/stack cost — the host-side marshal that precedes every launch.
fn marshal_path() {
    println!("--- operand gather/stack cost ---");
    let mut rng = Rng::new(1);
    let bench = Bencher::new(5, 30);
    let mut table = Table::new(&["operation", "R", "cost", "per_problem"]);
    for r in [8usize, 32, 64] {
        let parts: Vec<HostTensor> = (0..r)
            .map(|_| HostTensor::random(&[256, 1152], &mut rng))
            .collect();
        let refs: Vec<&HostTensor> = parts.iter().collect();
        let s = bench.summarize(|| {
            std::hint::black_box(HostTensor::stack(&refs, r));
        });
        table.row(&[
            "stack conv2_2 lhs".into(),
            r.to_string(),
            fmt_secs(s.mean),
            fmt_secs(s.mean / r as f64),
        ]);
        // Preallocated variant (the hot-loop path).
        let mut out = HostTensor::zeros(&[1]);
        let s2 = bench.summarize(|| {
            HostTensor::stack_into(&refs, r, &mut out);
            std::hint::black_box(&out);
        });
        table.row(&[
            "stack_into (pooled)".into(),
            r.to_string(),
            fmt_secs(s2.mean),
            fmt_secs(s2.mean / r as f64),
        ]);
    }
    table.emit("perf_marshal");
}

/// Decompose a served request's cost: schedule / marshal / execute.
fn end_to_end_components() {
    println!("--- end-to-end component breakdown (real path, 8 mlp tenants) ---");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/ not built");
        return;
    }
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        artifacts_dir: dir.into(),
        tenants: (0..8)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: "mlp".into(),
                batch: 1,
                slo_ms: 1000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    coord.warmup().unwrap();
    let mut rng = Rng::new(5);
    let rounds = 50usize;
    let mut service = 0.0f64;
    let mut total = 0.0f64;
    let mut served = 0usize;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for t in 0..8 {
            let p = coord.random_payload(t, &mut rng);
            coord.submit(t, p).unwrap();
        }
        for r in coord.run_until_drained().unwrap() {
            service += r.service_s;
            total += r.latency_s;
            served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = coord.engine().stats();
    let fstats = coord.fusion_cache_stats();
    let mut table = Table::new(&["metric", "value"]);
    table.row(&["requests served".into(), served.to_string()]);
    table.row(&["throughput".into(), format!("{:.0} req/s", served as f64 / wall)]);
    table.row(&["mean service (in-executable)".into(), fmt_secs(service / served as f64)]);
    table.row(&["mean e2e latency".into(), fmt_secs(total / served as f64)]);
    table.row(&["steady-state compiles".into(), stats.compiles.to_string()]);
    table.row(&["fusion-cache hit rate".into(), format!("{:.1}%", fstats.hit_rate() * 100.0)]);
    table.emit("perf_e2e_components");
    println!(
        "target check: compiles stay at the warmup count; hit rate ~100% in\n\
         steady state; service dominates latency (marshal amortized)."
    );
}
