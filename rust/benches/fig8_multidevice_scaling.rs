//! Figure 8 (extension) — aggregate FLOP throughput scaling across a
//! multi-device pool, SpaceTime vs TimeMux.
//!
//! The paper fills ONE V100 with space-time batching; production serving
//! (ROADMAP north star) scales past a single device. D-STACK
//! (arXiv:2304.13541) shows spatio-temporal scheduling across GPU
//! partitions multiplies throughput; this bench reproduces that curve on
//! the simulator's device pool: tenants sharded least-loaded with
//! shape-class affinity (`coordinator::placement`), each device running an
//! independent space-time round loop.
//!
//! Expected shape: SpaceTime aggregate throughput increases monotonically
//! from 1 → 4 devices and dominates TimeMux at every pool size; per-device
//! throughput stays roughly flat (sharding does not dilute fusion, because
//! placement keeps classes whole until they outgrow a fair share).

use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::util::bench::{banner, fmt_flops, BenchJson, Table};
use stgpu::workload::sgemm_tenants;

fn main() {
    banner(
        "Figure 8: aggregate throughput vs pool size (1-4 V100s)",
        "space-time scales ~linearly across devices; time-mux stays far below",
    );
    let shape = GemmShape::RESNET18_CONV2_2;
    let tenants = 96;
    let iters = 8;
    let max_batch = 32;
    let workloads = sgemm_tenants(tenants, iters, shape);

    let mut table = Table::new(&[
        "devices",
        "space_time_agg",
        "st_scaling",
        "time_mux_agg",
        "tm_scaling",
        "st/tm",
        "st_per_device",
    ]);
    let mut st_base = 0.0;
    let mut tm_base = 0.0;
    let mut st_prev = 0.0;
    let mut monotone = true;
    for devices in 1..=4usize {
        let st_cfg = SimConfig::new(DeviceSpec::v100(), Policy::SpaceTime { max_batch });
        let st = gpusim::run_pool(&st_cfg, &workloads, devices);
        let tm_cfg = SimConfig::new(DeviceSpec::v100(), Policy::TimeMux);
        let tm = gpusim::run_pool(&tm_cfg, &workloads, devices);
        let st_agg = st.throughput_flops();
        let tm_agg = tm.throughput_flops();
        if devices == 1 {
            st_base = st_agg;
            tm_base = tm_agg;
        }
        if st_agg <= st_prev {
            monotone = false;
        }
        st_prev = st_agg;
        let per_device: f64 = (0..devices)
            .map(|d| st.device_throughput(d))
            .sum::<f64>()
            / devices as f64;
        table.row(&[
            devices.to_string(),
            fmt_flops(st_agg),
            format!("{:.2}x", st_agg / st_base),
            fmt_flops(tm_agg),
            format!("{:.2}x", tm_agg / tm_base),
            format!("{:.1}x", st_agg / tm_agg),
            fmt_flops(per_device),
        ]);
    }
    table.emit("fig8_multidevice_scaling");
    // throughput = SpaceTime aggregate FLOP/s at the 4-device point.
    BenchJson::new("fig8_multidevice_scaling")
        .throughput(st_prev)
        .scale(4.0)
        .write();
    println!(
        "shape check: SpaceTime aggregate throughput {} monotonically 1 -> 4 \
         devices\n(asserted in rust/tests/integration_multidevice.rs); \
         placement keeps\nsame-class tenants co-located so per-device fusion \
         (and per-device\nthroughput) is preserved as the pool grows.",
        if monotone { "increases" } else { "FAILED to increase" }
    );
}
