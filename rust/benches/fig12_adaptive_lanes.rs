//! Figure 12 (extension) — adaptive space-time control: the
//! [`AdaptiveController`] re-deciding the resident lane count online vs
//! every static `lanes` setting, over a phase-shifting trace.
//!
//! The paper's core claim is a *dynamic* space-time scheduler; after the
//! spatial-lane PR our `lanes` knob was frozen at config-load time, so an
//! operator had to guess one split for a workload whose optimal split
//! changes with the offered load (D-STACK's per-workload GPU-percentage
//! knee, arXiv:2304.13541; DARIS's demand-driven partitioning,
//! arXiv:2504.08795). This bench replays ONE trace through the real
//! `SpaceTimeSched` (+ `Scheduler::set_lanes`) at static lanes = 1 / 2 / 4
//! and under the controller, on a simulated clock with gpusim
//! ground-truth launch durations, and asserts the adaptive run matches or
//! beats the best static setting per phase and strictly beats every
//! static setting on the whole trace, at no SLO-attainment loss.
//!
//! Three load phases:
//! * **A — low-rate latency-critical**: deterministic 25 ms waves of two
//!   device-filling GEMM classes (occupancy-saturated: concurrent lanes
//!   stretch each launch by ~n×, so overlap buys no makespan and costs
//!   latency). Every configuration keeps the 11.5 ms SLO here (waves are
//!   only 2 launches wide), and the controller learns the measured 2-lane
//!   stretch for free.
//! * **B — high-rate batchy**: Poisson floods of four small GEMM classes
//!   whose fused launches underfill the device — the fig10 regime where
//!   4 concurrent lanes nearly double throughput. Static 1/2 saturate and
//!   shed deadline after deadline; the controller must scale out.
//! * **C — mixed**: 25 ms waves of all four big classes (4-launch waves:
//!   4 resident lanes stretch each launch past the SLO, 1–2 lanes keep
//!   it) plus a trickle of batch traffic. Static 4 — phase B's winner —
//!   now misses every wave; the controller must scale back in.
//!
//! The y-axis (and the whole-trace comparison) is **SLO-met throughput**
//! (goodput): requests completed within their deadline per second — the
//! "throughput subject to SLO feasibility" utility the controller
//! optimizes. Workload constants were tuned numerically against this cost
//! model with `scripts/tune_fig12.py` (a python mirror of the replay, the
//! roofline math, and the controller); keep the two in sync.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use stgpu::coordinator::scheduler::SpaceTimeSched;
use stgpu::coordinator::{
    AdaptiveController, ControlSignals, ControllerParams, Decision, QueueSet, RequestContext,
    Scheduler, ShapeClass, SignalTracker,
};
use stgpu::gpusim::cost::{kernel_service_time, CostCtx};
use stgpu::gpusim::{DeviceSpec, GemmShape, KernelDesc};
use stgpu::util::bench::{banner, BenchJson, Table};
use stgpu::util::prng::Rng;
use stgpu::util::stats;

/// Device-filling "latency-critical" classes: ~8200 CTAs per problem, so
/// occupancy is saturated at any lane split and co-location stretches a
/// launch by ~n× — overlap never pays for these.
const LAT_CLASSES: [ShapeClass; 4] = [
    ShapeClass { kind: "batched_gemm", m: 8192, n: 8192, k: 128 },
    ShapeClass { kind: "batched_gemm", m: 8192, n: 8064, k: 128 },
    ShapeClass { kind: "batched_gemm", m: 8064, n: 8192, k: 128 },
    ShapeClass { kind: "batched_gemm", m: 8064, n: 8064, k: 128 },
];
/// Small underfilling classes (fig10's regime): concurrent lanes nearly
/// double aggregate throughput.
const BATCH_CLASSES: [ShapeClass; 4] = [
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1024 },
];
const N_LAT: usize = 8; // two tenants per lat class (ids 0..8)
const N_BATCH: usize = 8; // two tenants per batch class (ids 8..16)
const LAT_SLO_S: f64 = 0.0115;
const BATCH_SLO_S: f64 = 0.400;
const MAX_BATCH: usize = 16;
/// Phase spans (seconds): A latency-critical, B batchy, C mixed.
const PH_A: f64 = 1.0;
const PH_B: f64 = 1.5;
const PH_C: f64 = 2.0;
const HORIZON: f64 = PH_A + PH_B + PH_C;
const WAVE_PERIOD_S: f64 = 0.025;
const B_BATCH_RPS: f64 = 68_000.0;
const C_BATCH_RPS: f64 = 200.0;
const SEED: u64 = 1042;
/// Controller knobs (see ControllerParams below): short dwell so phase
/// transitions resolve within a few waves.
const DWELL_ROUNDS: u32 = 4;
const IMPROVEMENT: f64 = 0.10;

fn tenant_class(t: usize) -> ShapeClass {
    if t < N_LAT {
        LAT_CLASSES[t / 2]
    } else {
        BATCH_CLASSES[(t - N_LAT) / 2]
    }
}

fn tenant_slo_s(t: usize) -> f64 {
    if t < N_LAT {
        LAT_SLO_S
    } else {
        BATCH_SLO_S
    }
}

fn phase_of(t_arrival: f64) -> usize {
    if t_arrival < PH_A {
        0
    } else if t_arrival < PH_A + PH_B {
        1
    } else {
        2
    }
}

/// The phase-shifting trace: deterministic lat waves (A: first two
/// classes; C: all four) + Poisson batch floods (heavy in B, light in C).
fn trace() -> Vec<(f64, usize)> {
    let mut reqs: Vec<(f64, usize)> = Vec::new();
    let mut k = 1usize;
    while k as f64 * WAVE_PERIOD_S < PH_A {
        for t in 0..4 {
            reqs.push((k as f64 * WAVE_PERIOD_S, t));
        }
        k += 1;
    }
    let mut k = 1usize;
    while PH_A + PH_B + k as f64 * WAVE_PERIOD_S < HORIZON {
        for t in 0..N_LAT {
            reqs.push((PH_A + PH_B + k as f64 * WAVE_PERIOD_S, t));
        }
        k += 1;
    }
    let mut rng = Rng::new(SEED);
    for t in N_LAT..N_LAT + N_BATCH {
        for (t0, t1, rate) in [
            (PH_A, PH_A + PH_B, B_BATCH_RPS / N_BATCH as f64),
            (PH_A + PH_B, HORIZON, C_BATCH_RPS / N_BATCH as f64),
        ] {
            let mut x = t0 + rng.gen_exp(rate);
            while x < t1 {
                reqs.push((x, t));
                x += rng.gen_exp(rate);
            }
        }
    }
    reqs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    reqs
}

/// gpusim ground truth for a fused launch of `r` problems of `class` with
/// `active` lanes concurrently resident (same construction as fig10).
fn ground_truth(spec: &DeviceSpec, class: ShapeClass, r: usize, active: usize) -> f64 {
    let shape =
        GemmShape::new(class.m.max(1) as u32, class.n.max(1) as u32, class.k.max(1) as u32);
    let mut merged = KernelDesc::sgemm(0, shape);
    let r = r.max(1);
    merged.flops *= r as f64;
    merged.bytes *= r as f64;
    merged.ctas = merged.ctas.saturating_mul(r as u32);
    merged.fused = r as u32;
    let active = active.max(1);
    spec.launch_overhead_s
        + kernel_service_time(
            spec,
            &merged,
            &CostCtx {
                sms: spec.sms as f64 / active as f64,
                concurrency: active as u32,
                static_bw_partition: false,
            },
        )
}

struct RunResult {
    label: String,
    /// Whole-trace SLO-met throughput, req/s (hits / HORIZON).
    goodput_rps: f64,
    /// Per-phase SLO-met throughput (hits of requests ARRIVING in the
    /// phase, over the phase span).
    phase_goodput: [f64; 3],
    attainment: f64,
    completed: u64,
    reconfigs: u64,
    lane_counts_used: usize,
    latencies: Vec<f64>,
}

/// Replay the trace through the real SpaceTimeSched on a simulated clock.
/// `adaptive = false` keeps `static_lanes` for the whole run; `true` lets
/// the controller re-target the scheduler every dwell window via
/// `set_lanes` — exactly the driver's reconfiguration path.
fn run(static_lanes: usize, adaptive: bool) -> RunResult {
    let spec = DeviceSpec::v100();
    let tr = trace();
    let base = Instant::now();
    let mut sched = SpaceTimeSched::new(vec![1, 2, 4, 8, 16, 32, 64], MAX_BATCH)
        .spatial_lanes(static_lanes, None);
    let mut ctl = adaptive.then(|| {
        AdaptiveController::new(
            ControllerParams {
                max_lanes: 4,
                max_depth: 1, // the replay models no pipeline
                dwell_rounds: DWELL_ROUNDS,
                improvement: IMPROVEMENT,
                slo_target: 0.99,
            },
            Decision { lanes: 1, depth: 1 },
        )
    });
    if adaptive {
        sched.set_lanes(1);
    }
    let mut tracker = SignalTracker::default();
    let mut q = QueueSet::new(N_LAT + N_BATCH, 1 << 16);
    let mut idx = 0usize;
    let mut t = 0.0f64;
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut win_hits = 0u64;
    let mut win_misses = 0u64;
    let mut phase_hits = [0u64; 3];
    let mut completed = 0u64;
    let mut lanes_seen: HashMap<usize, u64> = HashMap::new();
    let mut lanes_now = static_lanes;
    let mut latencies = Vec::with_capacity(tr.len());
    loop {
        while idx < tr.len() && tr[idx].0 <= t {
            let (arr, tenant) = tr[idx];
            let arrived = base + Duration::from_secs_f64(arr);
            // Context-carrying API: deadline rides the RequestContext.
            let ctx = RequestContext::new(tenant)
                .with_budget(Duration::from_secs_f64(tenant_slo_s(tenant)));
            q.push(ctx.into_request(idx as u64, tenant_class(tenant), vec![], arrived, Duration::ZERO))
                .expect("bench queues are effectively unbounded");
            idx += 1;
        }
        if q.is_empty() {
            match tr.get(idx) {
                Some(&(next, _)) => {
                    t = next; // idle-skip to the next arrival
                    continue;
                }
                None => break,
            }
        }
        if let Some(ctl) = &mut ctl {
            if ctl.tick() {
                let now = base + Duration::from_secs_f64(t);
                let signals = ControlSignals {
                    backlog: q.total_pending(),
                    arrival_rate: q.arrival_rate(now),
                    launches_per_round: tracker.launches_per_round(),
                    requests_per_round: tracker.requests_per_round(),
                    mean_launch_s: tracker.mean_launch_s(),
                    plan_s: 0.0,
                    stretch: tracker.stretch_table(4, |n| spec.lane_stretch(n as u32)),
                    slo_attainment: if win_hits + win_misses > 0 {
                        Some(win_hits as f64 / (win_hits + win_misses) as f64)
                    } else {
                        None
                    },
                    min_slo_s: LAT_SLO_S,
                    steal_rate: 0.0,
                };
                let decision = ctl.decide(&signals);
                // Verdicts are consumed at every dwell boundary (a
                // boundary with verdicts always evaluates — mirrors the
                // driver's window accounting).
                win_hits = 0;
                win_misses = 0;
                if decision.lanes != lanes_now {
                    lanes_now = decision.lanes;
                    sched.set_lanes(lanes_now);
                }
            }
        }
        let now = base + Duration::from_secs_f64(t);
        let plan = sched.plan_round_at(&mut q, now);
        let drained = plan.drained;
        let active = plan.lanes_used().max(1);
        *lanes_seen.entry(active).or_default() += 1;
        let mut lane_time = vec![0.0f64; plan.n_lanes.max(1)];
        for (i, launch) in plan.launches.iter().enumerate() {
            let dur = ground_truth(&spec, launch.class, launch.r_bucket, active);
            if ctl.is_some() {
                let solo = ground_truth(&spec, launch.class, launch.r_bucket, 1);
                tracker.observe_launch(solo);
                if active > 1 {
                    tracker.observe_stretch(active, dur / solo.max(1e-12));
                }
            }
            let lane = plan.lane(i);
            lane_time[lane] += dur;
            let done = base + Duration::from_secs_f64(t + lane_time[lane]);
            for e in &launch.entries {
                completed += 1;
                let arr_s = e.arrived.duration_since(base).as_secs_f64();
                latencies.push(done.duration_since(e.arrived).as_secs_f64());
                if done <= e.deadline {
                    hits += 1;
                    win_hits += 1;
                    phase_hits[phase_of(arr_s)] += 1;
                } else {
                    misses += 1;
                    win_misses += 1;
                }
            }
        }
        if ctl.is_some() {
            tracker.observe_round(plan.launches.len(), drained, 0.0);
        }
        t += lane_time.iter().cloned().fold(0.0, f64::max);
    }
    let spans = [PH_A, PH_B, PH_C];
    RunResult {
        label: if adaptive { "adaptive".into() } else { format!("lanes={static_lanes}") },
        goodput_rps: hits as f64 / HORIZON,
        phase_goodput: [
            phase_hits[0] as f64 / spans[0],
            phase_hits[1] as f64 / spans[1],
            phase_hits[2] as f64 / spans[2],
        ],
        attainment: hits as f64 / (hits + misses).max(1) as f64,
        completed,
        reconfigs: ctl.as_ref().map_or(0, |c| c.reconfigs()),
        lane_counts_used: lanes_seen.len(),
        latencies,
    }
}

fn main() {
    banner(
        "Figure 12: adaptive lane control vs static settings (phase-shifting trace)",
        "adaptive >= best static per phase, > every static overall, no SLO-attainment loss",
    );
    let statics: Vec<RunResult> = [1usize, 2, 4].iter().map(|&l| run(l, false)).collect();
    let adaptive = run(1, true);

    let mut table = Table::new(&[
        "config",
        "goodput_rps",
        "slo_attainment",
        "goodput_A",
        "goodput_B",
        "goodput_C",
        "completed",
        "reconfigs",
    ]);
    for r in statics.iter().chain(std::iter::once(&adaptive)) {
        table.row(&[
            r.label.clone(),
            format!("{:.1}", r.goodput_rps),
            format!("{:.4}", r.attainment),
            format!("{:.1}", r.phase_goodput[0]),
            format!("{:.1}", r.phase_goodput[1]),
            format!("{:.1}", r.phase_goodput[2]),
            r.completed.to_string(),
            r.reconfigs.to_string(),
        ]);
    }
    table.emit("fig12_adaptive_lanes");

    // Conservation: every configuration completes the whole trace.
    for s in &statics {
        assert_eq!(
            s.completed, adaptive.completed,
            "{} completed a different request count",
            s.label
        );
    }
    // The controller actually adapted: reconfigurations happened and the
    // replay executed rounds at several distinct lane counts.
    assert!(adaptive.reconfigs > 0, "controller never reconfigured");
    assert!(
        adaptive.lane_counts_used >= 2,
        "adaptive run never changed its resident lane count"
    );
    // Per phase: adaptive matches or beats the best static setting
    // (tolerance for its transition windows at phase boundaries).
    for (p, name) in ["A", "B", "C"].iter().enumerate() {
        let best = statics.iter().map(|s| s.phase_goodput[p]).fold(0.0f64, f64::max);
        assert!(
            adaptive.phase_goodput[p] >= best * 0.95,
            "phase {name}: adaptive goodput {:.1} below best static {:.1}",
            adaptive.phase_goodput[p],
            best
        );
    }
    // Whole trace: strictly more SLO-met throughput than EVERY static
    // setting, at no attainment loss.
    for s in &statics {
        assert!(
            adaptive.goodput_rps > s.goodput_rps,
            "overall: adaptive {:.1} req/s must strictly beat {} at {:.1}",
            adaptive.goodput_rps,
            s.label,
            s.goodput_rps
        );
        assert!(
            adaptive.attainment >= s.attainment,
            "overall: adaptive attainment {:.4} fell below {} at {:.4}",
            adaptive.attainment,
            s.label,
            s.attainment
        );
    }
    let mut lat = adaptive.latencies.clone();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "shape check: adaptive {:.0} req/s SLO-met vs statics {:.0}/{:.0}/{:.0}; \
         attainment {:.4} vs {:.4}/{:.4}/{:.4}; {} reconfigurations across \
         {} lane counts.",
        adaptive.goodput_rps,
        statics[0].goodput_rps,
        statics[1].goodput_rps,
        statics[2].goodput_rps,
        adaptive.attainment,
        statics[0].attainment,
        statics[1].attainment,
        statics[2].attainment,
        adaptive.reconfigs,
        adaptive.lane_counts_used,
    );
    BenchJson::new("fig12_adaptive_lanes")
        .throughput(adaptive.goodput_rps)
        .p50_s(stats::percentile(&lat, 50.0))
        .p99_s(stats::percentile(&lat, 99.0))
        .slo_attainment(adaptive.attainment)
        .write();
}
