//! Figure 10 (extension) — concurrent spatial lanes: aggregate throughput
//! and SLO attainment of the lane-balanced SpaceTime scheduler at
//! lanes = 1 / 2 / 4 under a bursty multi-class trace.
//!
//! The paper's headline (3.23x over space-only, 7.73x over time-only)
//! comes from *combining* temporal fusion with spatial co-execution; until
//! now our rounds executed every fused launch back-to-back on one implicit
//! stream. This bench replays one trace through the same scheduler at
//! different lane counts on a simulated clock. Launch durations are gpusim
//! ground truth: a launch sharing the device with `active - 1` other lanes
//! runs on a static `sms / active` SM fraction with the deterministic
//! interference derate — the concave occupancy curve is what makes planned
//! spatial sharing profitable for super-kernels too small to fill the
//! device alone (D-STACK, arXiv:2304.13541). Every measured duration feeds
//! the cost model's co-location interference term
//! (`CostModel::observe_concurrent`), closing the calibration loop the
//! driver runs in production (DARIS, arXiv:2504.08795).
//!
//! Asserted at the bottom (the ISSUE acceptance claims): lanes = 2 and
//! lanes = 4 aggregate throughput strictly above lanes = 1 at >= equal SLO
//! attainment, with the interference-model calibration error reported.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use stgpu::coordinator::scheduler::SpaceTimeSched;
use stgpu::coordinator::{CostModel, InferenceRequest, Priority, QueueSet, Scheduler, ShapeClass};
use stgpu::gpusim::cost::{kernel_service_time, CostCtx};
use stgpu::gpusim::{DeviceSpec, GemmShape, KernelDesc};
use stgpu::util::bench::{banner, BenchJson, Table};
use stgpu::workload::arrivals::{ArrivalProcess, RequestTrace};

/// Four distinct shape classes (two tenants each): every saturated round
/// plans ~4 super-kernels of ~128 CTAs — each too small to fill 80 SMs.
const CLASSES: [ShapeClass; 4] = [
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1024 },
];
const N_TENANTS: usize = 8; // tenant t serves CLASSES[t / 2]
const SLO_S: f64 = 0.010;
const MAX_BATCH: usize = 16;
const HORIZON_S: f64 = 1.0;
const SEED: u64 = 1042;

fn class_of(tenant: usize) -> ShapeClass {
    CLASSES[(tenant / 2).min(CLASSES.len() - 1)]
}

fn trace() -> RequestTrace {
    // Bursty arrivals strictly above the single-lane fused-service
    // capacity (~37k req/s) even in the low phase, and around the 2-lane
    // capacity in the high one: the serial scheduler is saturated for the
    // whole horizon (its backlog never drains, so the comparison never
    // degenerates into identical idle-skipping), while multi-lane runs
    // drain the same trace with bounded backlog — exactly the regime where
    // planned spatial co-execution pays.
    let processes: Vec<(usize, ArrivalProcess)> = (0..N_TENANTS)
        .map(|t| {
            (t, ArrivalProcess::Bursty { low: 5000.0, high: 10_000.0, dwell: 0.1 })
        })
        .collect();
    RequestTrace::generate(&processes, SEED, HORIZON_S)
}

/// gpusim ground truth for a fused launch of `r` problems of `class` with
/// `active` lanes concurrently resident.
fn ground_truth(spec: &DeviceSpec, class: ShapeClass, r: usize, active: usize) -> f64 {
    let shape =
        GemmShape::new(class.m.max(1) as u32, class.n.max(1) as u32, class.k.max(1) as u32);
    let mut merged = KernelDesc::sgemm(0, shape);
    let r = r.max(1);
    merged.flops *= r as f64;
    merged.bytes *= r as f64;
    merged.ctas = merged.ctas.saturating_mul(r as u32);
    merged.fused = r as u32;
    let active = active.max(1);
    spec.launch_overhead_s
        + kernel_service_time(
            spec,
            &merged,
            &CostCtx {
                sms: spec.sms as f64 / active as f64,
                concurrency: active as u32,
                static_bw_partition: false,
            },
        )
}

struct LaneResult {
    lanes: usize,
    completed: u64,
    hits: u64,
    misses: u64,
    makespan_s: f64,
    launches: u64,
    multi_lane_rounds: u64,
    calibration_2: f64,
}

impl LaneResult {
    fn attainment(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    fn throughput_rps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.makespan_s
        }
    }
}

/// Replay the trace at one lane count on a simulated clock. Within a
/// round, each lane executes its launches serially while lanes overlap;
/// the round ends when the slowest lane drains (the driver's barrier).
fn run_lanes(lanes: usize) -> LaneResult {
    let spec = DeviceSpec::v100();
    let tr = trace();
    let base = Instant::now();
    let cost = Arc::new(Mutex::new(CostModel::new()));
    let mut sched = SpaceTimeSched::new(vec![1, 2, 4, 8, 16, 32, 64], MAX_BATCH)
        .spatial_lanes(lanes, Some(cost.clone()));
    let mut q = QueueSet::new(N_TENANTS, 1 << 16);
    let mut idx = 0usize;
    let mut t = 0.0f64;
    let mut res = LaneResult {
        lanes,
        completed: 0,
        hits: 0,
        misses: 0,
        makespan_s: 0.0,
        launches: 0,
        multi_lane_rounds: 0,
        calibration_2: 0.0,
    };
    loop {
        while idx < tr.requests.len() && tr.requests[idx].t_arrival <= t {
            let r = tr.requests[idx];
            let arrived = base + Duration::from_secs_f64(r.t_arrival);
            q.push(InferenceRequest {
                id: idx as u64,
                tenant: r.tenant,
                class: class_of(r.tenant),
                payload: vec![],
                arrived,
                deadline: arrived + Duration::from_secs_f64(SLO_S),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .expect("bench queues are effectively unbounded");
            idx += 1;
        }
        if q.is_empty() {
            match tr.requests.get(idx) {
                Some(next) => {
                    t = next.t_arrival; // idle-skip to the next arrival
                    continue;
                }
                None => break,
            }
        }
        let now = base + Duration::from_secs_f64(t);
        let plan = sched.plan_round_at(&mut q, now);
        let active = plan.lanes_used().max(1);
        if active > 1 {
            res.multi_lane_rounds += 1;
        }
        let mut lane_time = vec![0.0f64; plan.n_lanes.max(1)];
        for (i, launch) in plan.launches.iter().enumerate() {
            let dur = ground_truth(&spec, launch.class, launch.r_bucket, active);
            let lane = plan.lane(i);
            lane_time[lane] += dur;
            cost.lock().unwrap().observe_concurrent(
                launch.class,
                launch.r_bucket,
                active,
                dur,
            );
            res.launches += 1;
            // Every member completes when its launch's lane cursor does.
            let done = base + Duration::from_secs_f64(t + lane_time[lane]);
            for e in &launch.entries {
                res.completed += 1;
                if done <= e.deadline {
                    res.hits += 1;
                } else {
                    res.misses += 1;
                }
            }
        }
        t += lane_time.iter().cloned().fold(0.0, f64::max);
    }
    res.makespan_s = t;
    res.calibration_2 = cost.lock().unwrap().lane_calibration_error(2);
    res
}

fn main() {
    banner(
        "Figure 10: concurrent spatial lanes (SpaceTime, bursty multi-class load)",
        "lane-balanced rounds strictly raise aggregate throughput at >= equal SLO attainment",
    );
    let results: Vec<LaneResult> = [1usize, 2, 4].iter().map(|&l| run_lanes(l)).collect();

    let mut table = Table::new(&[
        "lanes",
        "completed",
        "slo_attainment",
        "throughput_rps",
        "makespan_s",
        "launches",
        "multi_lane_rounds",
        "calib_err_2lanes",
    ]);
    for r in &results {
        table.row(&[
            r.lanes.to_string(),
            r.completed.to_string(),
            format!("{:.4}", r.attainment()),
            format!("{:.1}", r.throughput_rps()),
            format!("{:.3}", r.makespan_s),
            r.launches.to_string(),
            r.multi_lane_rounds.to_string(),
            format!("{:.4}", r.calibration_2),
        ]);
    }
    table.emit("fig10_spatial_lanes");

    let serial = &results[0];
    for r in &results[1..] {
        assert_eq!(
            r.completed, serial.completed,
            "every lane count must complete the whole trace"
        );
        assert!(
            r.throughput_rps() > serial.throughput_rps(),
            "lanes={} throughput {:.1} must strictly beat lanes=1 {:.1}",
            r.lanes,
            r.throughput_rps(),
            serial.throughput_rps()
        );
        assert!(
            r.attainment() >= serial.attainment(),
            "lanes={} attainment {:.4} must not fall below lanes=1 {:.4}",
            r.lanes,
            r.attainment(),
            serial.attainment()
        );
        assert!(r.multi_lane_rounds > 0, "lanes={} never overlapped", r.lanes);
        assert!(
            r.calibration_2 < 0.25,
            "interference calibration error {:.4} should be bounded",
            r.calibration_2
        );
    }
    println!(
        "shape check: lanes=2 throughput {:.1} rps ({:.2}x over serial {:.1}), \
         lanes=4 {:.1} rps ({:.2}x); attainment {:.4} / {:.4} / {:.4}; \
         2-lane interference calibration error {:.4} after {} overlapped rounds.",
        results[1].throughput_rps(),
        results[1].throughput_rps() / serial.throughput_rps().max(1e-9),
        serial.throughput_rps(),
        results[2].throughput_rps(),
        results[2].throughput_rps() / serial.throughput_rps().max(1e-9),
        serial.attainment(),
        results[1].attainment(),
        results[2].attainment(),
        results[1].calibration_2,
        results[1].multi_lane_rounds,
    );
    BenchJson::new("fig10_spatial_lanes")
        .throughput(results[1].throughput_rps())
        .slo_attainment(results[1].attainment())
        .write();
}
