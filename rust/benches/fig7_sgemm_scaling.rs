//! Figure 7 — SGEMM throughput scaling with concurrent problems R.
//!
//! Paper claim: for the ResNet-18 conv2_2 GEMM (M=256, N=128, K=1152),
//! inter-model kernel batching scales throughput with R far better than
//! either baseline — 7.73x over time-only and 3.23x over space-only
//! multiplexing (geomean over the R sweep).
//!
//! Two measurements:
//!  1. V100 simulator sweep (the paper's testbed shape), R = 2..120.
//!  2. Real PJRT-CPU execution of the same merge — R problems as R
//!     singleton launches vs one batched super-kernel — demonstrating the
//!     launch-amortization mechanism with real numerics.

use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::runtime::{HostTensor, PjrtEngine};
use stgpu::util::bench::{banner, fmt_flops, BenchJson, Bencher, Table};
use stgpu::util::prng::Rng;
use stgpu::util::stats::geomean;
use stgpu::workload::sgemm_tenants;

fn main() {
    banner(
        "Figure 7: conv2_2 SGEMM throughput vs concurrent problems R",
        "space-time 7.73x over time-only, 3.23x over space-only (geomean)",
    );
    simulated_sweep();
    real_pjrt_merge();
}

fn simulated_sweep() {
    println!("--- V100 simulator sweep (paper testbed shape) ---");
    let spec = DeviceSpec::v100();
    let shape = GemmShape::RESNET18_CONV2_2;
    let iters = 20;
    let mut table = Table::new(&["R", "time_only", "space_only", "space_time", "st/time", "st/space"]);
    let mut r_time = Vec::new();
    let mut r_space = Vec::new();
    for r in [2usize, 5, 10, 20, 40, 60, 80, 100, 120] {
        let tput = |policy: Policy| {
            let cfg = SimConfig::new(spec.clone(), policy);
            gpusim::run(&cfg, &sgemm_tenants(r, iters, shape)).throughput_flops()
        };
        let time = tput(Policy::TimeMux);
        let space = tput(Policy::SpaceMuxMps { anomaly_seed: 9 });
        let st = tput(Policy::SpaceTime { max_batch: 128 });
        r_time.push(st / time);
        r_space.push(st / space);
        table.row(&[
            r.to_string(),
            fmt_flops(time),
            fmt_flops(space),
            fmt_flops(st),
            format!("{:.2}x", st / time),
            format!("{:.2}x", st / space),
        ]);
    }
    table.emit("fig7_sim_sweep");
    println!(
        "geomean speedup — over time-only: {:.2}x (paper 7.73x), \
         over space-only: {:.2}x (paper 3.23x)",
        geomean(&r_time),
        geomean(&r_space)
    );
    // Schema note: throughput carries the geomean space-time/time-only
    // speedup (the figure's headline ratio, not req/s).
    BenchJson::new("fig7_sgemm_scaling")
        .throughput(geomean(&r_time))
        .write();
}

fn real_pjrt_merge() {
    println!("\n--- Real PJRT-CPU merge (launch amortization, real numerics) ---");
    println!("(operands pre-uploaded to device buffers, as in the paper's");
    println!(" §4.1: \"data is preallocated on the device\"; execute_b only)");
    let Ok(engine) = PjrtEngine::new("artifacts") else {
        println!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let mut rng = Rng::new(7);
    for (shape_name, m, n, k) in [
        ("rnn_matvec", 512usize, 1usize, 512usize),
        ("conv2_2", 256, 128, 1152),
    ] {
        real_pjrt_shape(&engine, &mut rng, shape_name, m, n, k);
    }
}

fn real_pjrt_shape(
    engine: &PjrtEngine,
    rng: &mut Rng,
    shape_name: &str,
    m: usize,
    n: usize,
    k: usize,
) {
    println!("\n[{shape_name}: M={m} N={n} K={k}]");
    let flops_per_problem = 2.0 * (m * n * k) as f64;
    let bench = Bencher::new(2, 8);
    let mut table = Table::new(&["R", "R_singleton_launches", "one_superkernel", "speedup"]);
    let mut speedups = Vec::new();
    for r in [2usize, 4, 8, 16, 32, 64] {
        // Per-problem inputs, uploaded once (device-resident).
        let problems: Vec<(HostTensor, HostTensor)> = (0..r)
            .map(|_| {
                (
                    HostTensor::random(&[1, m, k], rng),
                    HostTensor::random(&[1, k, n], rng),
                )
            })
            .collect();
        let dev_problems: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)> = problems
            .iter()
            .map(|(a, b)| (engine.to_device(a).unwrap(), engine.to_device(b).unwrap()))
            .collect();
        // Baseline: R singleton launches (time/space-only dispatch shape).
        let single = engine
            .load(&format!("gemm_{shape_name}_r1.xla"))
            .unwrap();
        let t_singles = bench
            .summarize(|| {
                for (a, b) in &dev_problems {
                    single.execute_buffers(&[a, b]).unwrap();
                }
            })
            .mean;
        // Super-kernel: one launch of the exact-R bucket, also pre-staged.
        let fused = engine
            .load(&format!("gemm_{shape_name}_r{r}.xla"))
            .unwrap();
        let a_parts: Vec<HostTensor> =
            problems.iter().map(|(a, _)| a.slice_problem(0)).collect();
        let b_parts: Vec<HostTensor> =
            problems.iter().map(|(_, b)| b.slice_problem(0)).collect();
        let a_stack = engine
            .to_device(&HostTensor::stack(&a_parts.iter().collect::<Vec<_>>(), r))
            .unwrap();
        let b_stack = engine
            .to_device(&HostTensor::stack(&b_parts.iter().collect::<Vec<_>>(), r))
            .unwrap();
        let t_fused = bench
            .summarize(|| {
                fused.execute_buffers(&[&a_stack, &b_stack]).unwrap();
            })
            .mean;
        let speedup = t_singles / t_fused;
        speedups.push(speedup);
        let total_flops = flops_per_problem * r as f64;
        table.row(&[
            r.to_string(),
            format!("{} ({})", stgpu::util::bench::fmt_secs(t_singles), fmt_flops(total_flops / t_singles)),
            format!("{} ({})", stgpu::util::bench::fmt_secs(t_fused), fmt_flops(total_flops / t_fused)),
            format!("{speedup:.2}x"),
        ]);
    }
    table.emit(&format!("fig7_pjrt_merge_{shape_name}"));
    println!(
        "geomean super-kernel speedup on real CPU-PJRT [{shape_name}]: {:.2}x\n\
         (mechanism check: fusing amortizes per-launch dispatch — decisive\n\
         for small kernels, negligible for ms-scale ones on this 1-core\n\
         host; batch-level *parallelism* needs parallel hardware, so the\n\
         V100-scaled shape comes from the simulator above)",
        geomean(&speedups)
    );
}
