//! Figure 14 (extension) — SLO-met goodput scaling across the cluster
//! tier, with a mid-run node kill/rejoin at the largest scale.
//!
//! The paper fills one V100; PR 7 scaled the *simulator* to cluster size;
//! this bench scales the *control plane*: the sequencer → node-workers →
//! in-order-committer cluster tier (`coordinator::cluster`) running 1, 4,
//! and 16 in-process nodes, each node a full scheduler/controller stack
//! over its own tenant set.
//!
//! Expected shape:
//! * SLO-met goodput scales with node count (per-node load is constant,
//!   so offered load — and, comfortably under SLO, goodput — grows
//!   linearly; the acceptance floor is 16 nodes ≥ 3x 1 node).
//! * A node killed mid-run at 16 nodes dents the SLO-met goodput of the
//!   kill window boundedly (its tenants re-place onto survivors; the
//!   stranded backlog is lost) rather than collapsing it, and the
//!   post-rejoin window recovers to ~pre-kill goodput.
//! * The 4-node parallel run's decision journal is bitwise identical to
//!   the serial re-execution (the determinism contract `stgpu replay`
//!   enforces; also asserted per-PR in rust/tests/cluster_replay.rs).

use stgpu::coordinator::cluster::{ClusterOpts, FaultOpts, RoundStats};
use stgpu::coordinator::run_cluster;
use stgpu::util::bench::{banner, BenchJson, Table};

/// SLO-met goodput (req/s) over a half-open round window.
fn window_goodput(rounds: &[RoundStats], round_s: f64, from: u64, to: u64) -> f64 {
    let hits: u64 = rounds
        .iter()
        .filter(|r| r.round >= from && r.round < to)
        .map(|r| r.hits)
        .sum();
    let dur = (to - from) as f64 * round_s;
    if dur > 0.0 {
        hits as f64 / dur
    } else {
        0.0
    }
}

fn main() {
    banner(
        "Figure 14: cluster scale-out (1 -> 4 -> 16 nodes) with kill/rejoin",
        "SLO-met goodput scales with nodes; a killed node dents, not collapses, attainment",
    );

    // --- Scaling sweep: constant per-node load, growing node count. ---
    let mut table = Table::new(&[
        "nodes",
        "offered",
        "completed",
        "goodput_rps",
        "scaling",
        "slo_att",
        "migrations",
    ]);
    let mut goodput = Vec::new();
    let mut att16 = 1.0;
    for &nodes in &[1usize, 4, 16] {
        let opts = ClusterOpts::demo(nodes);
        let report = run_cluster(&opts, true).expect("cluster run");
        assert!(report.conservation_ok(), "{nodes} nodes: requests not conserved");
        let g = report.goodput_rps();
        if goodput.is_empty() {
            assert!(g > 0.0, "1-node goodput must be positive");
        }
        if nodes == 16 {
            att16 = report.attainment();
        }
        table.row(&[
            nodes.to_string(),
            report.offered.to_string(),
            report.completed.to_string(),
            format!("{g:.1}"),
            format!("{:.2}x", g / goodput.first().copied().unwrap_or(g)),
            format!("{:.4}", report.attainment()),
            report.migrations.to_string(),
        ]);
        goodput.push(g);
    }
    let (g1, g4, g16) = (goodput[0], goodput[1], goodput[2]);
    assert!(
        g4 >= 1.5 * g1,
        "4-node goodput {g4:.1} < 1.5x the 1-node {g1:.1}"
    );
    // The ISSUE 8 acceptance floor (deliberately far under the ~linear
    // scaling a constant per-node load produces).
    assert!(
        g16 >= 3.0 * g1,
        "16-node goodput {g16:.1} < 3x the 1-node {g1:.1}"
    );

    // --- Determinism spot-check at 4 nodes: parallel == serial journal. ---
    let opts4 = ClusterOpts::demo(4);
    let par = run_cluster(&opts4, true).expect("parallel");
    let ser = run_cluster(&opts4, false).expect("serial");
    assert_eq!(
        par.journal.digest(),
        ser.journal.digest(),
        "4-node parallel journal diverged from serial re-execution"
    );
    println!(
        "determinism: 4-node parallel and serial journals share digest {}",
        par.journal.digest_hex()
    );

    // --- Kill/rejoin at 16 nodes: the dip must be bounded. ---
    let mut opts = ClusterOpts::demo(16);
    let (kill, rejoin) = (opts.rounds / 3, 2 * opts.rounds / 3);
    opts.fault = Some(FaultOpts { node: 3, kill_round: kill, rejoin_round: rejoin });
    let faulted = run_cluster(&opts, true).expect("faulted run");
    assert!(faulted.conservation_ok(), "faulted run: requests not conserved");
    assert_eq!(faulted.node_downs, 1);
    assert_eq!(faulted.node_ups, 1);
    let pre = window_goodput(&faulted.rounds, opts.round_s, 0, kill);
    let dip = window_goodput(&faulted.rounds, opts.round_s, kill, rejoin);
    let post = window_goodput(&faulted.rounds, opts.round_s, rejoin, opts.rounds);
    println!(
        "kill/rejoin: goodput pre={pre:.1} dip={dip:.1} post={post:.1} req/s \
         (node 3 down rounds {kill}..{rejoin})"
    );
    // Bounded, not collapsed: losing 1 of 16 nodes (plus its stranded
    // backlog) must keep the kill window above half the pre-kill goodput.
    assert!(
        dip >= 0.5 * pre,
        "kill window goodput {dip:.1} collapsed below 0.5x pre-kill {pre:.1}"
    );
    // And the rejoin must actually recover.
    assert!(
        post >= 0.9 * pre,
        "post-rejoin goodput {post:.1} did not recover to 0.9x pre-kill {pre:.1}"
    );

    table.emit("fig14_cluster_scaleout");
    // throughput = SLO-met goodput at the 16-node point (no fault).
    BenchJson::new("fig14_cluster_scaleout")
        .throughput(g16)
        .slo_attainment(att16)
        .scale(16.0)
        .write();
    println!(
        "shape check: goodput scales {:.2}x at 4 nodes and {:.2}x at 16 \
         (floor 3x); the kill window held {:.0}% of pre-kill goodput and \
         the post-rejoin window {:.0}%.",
        g4 / g1,
        g16 / g1,
        dip / pre * 100.0,
        post / pre * 100.0
    );
}
