//! Figure 16 (extension) — graceful degradation under overload through
//! the gateway tier: goodput of the full admission stack (auth →
//! validation → per-tenant token buckets → per-shard circuit breakers →
//! backend) as offered load sweeps 1x → 10x → 100x of backend capacity
//! across thousands of tenants.
//!
//! The claim the gateway exists for: a server WITHOUT admission control
//! melts under a 100x front — every queue fills, every request pays the
//! full queueing delay, goodput collapses. WITH the gateway, overload is
//! absorbed at the cheapest possible layer: per-tenant buckets clamp the
//! admitted stream to a sustainable aggregate just above capacity, and
//! the burst-credit flood at t = 0 (every bucket starts full) trips the
//! per-shard breakers exactly once, which shed the spike at the gateway
//! until the half-open probes confirm the shards have drained. Past the
//! transient, the admitted stream settles at ~1.2x capacity, the shards
//! run saturated, and goodput stays pinned at capacity no matter how
//! hard the front door is hammered.
//!
//! Everything runs on a virtual clock (the gateway takes `now`
//! explicitly) against a deterministic tick-capacity shard model: the
//! sweep is exactly reproducible — no real sockets, no real sleeps.
//!
//! Asserted at the bottom (the ISSUE acceptance claims): goodput at
//! 100x >= 0.8x the 1x capacity goodput; admitted-request p99 stays
//! bounded (no queueing collapse); every shard's breaker trips on the
//! 100x burst, sheds WITHOUT backend submissions, and recovers to
//! closed by the end of the run.

use std::time::{Duration, Instant};

use stgpu::config::{GatewayConfig, GatewayTenant, IsolationClass};
use stgpu::coordinator::{InferenceResponse, Reject, RequestContext};
use stgpu::runtime::HostTensor;
use stgpu::server::{BackendReply, BreakerState, Gateway, GatewayBackend, WireRequest};
use stgpu::util::bench::{banner, BenchJson, Table};
use stgpu::util::prng::Rng;
use stgpu::util::stats;

const N_TENANTS: usize = 2000;
const SHARDS: usize = 8;
/// Virtual-time tick; shard capacity is per tick.
const TICK_S: f64 = 0.001;
const HORIZON_TICKS: u64 = 1000;
/// Backend capacity: 5 per shard per tick = 40k requests/s total.
const CAP_PER_TICK: usize = 5;
const CAP_RPS: f64 = (CAP_PER_TICK * SHARDS) as f64 / TICK_S;
/// Aggregate sustained token rate relative to capacity: just above 1.0
/// so the shards run saturated but the steady overload fraction (~1/6)
/// stays far under the breaker threshold (1/2).
const RATE_OVER_CAP: f64 = 1.2;
const SEED: u64 = 1601;
/// Per-request deadline budget on the wire (all admitted requests
/// complete well inside it — the sweep measures shedding, not misses).
const BUDGET_MS: f64 = 50.0;

/// Deterministic shard model: each shard serves up to [`CAP_PER_TICK`]
/// submissions per tick at a latency that grows with its position in
/// the tick (a drained shard answers fast, a busy one slower), and
/// rejects the rest with `Overloaded`.
struct SimShards {
    counts: Vec<usize>,
    submits: u64,
    accepted: u64,
}

impl SimShards {
    fn new() -> Self {
        Self { counts: vec![0; SHARDS], submits: 0, accepted: 0 }
    }

    fn next_tick(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }
}

impl GatewayBackend for SimShards {
    fn devices(&self) -> usize {
        SHARDS
    }

    fn device_of(&self, tenant: usize) -> usize {
        tenant % SHARDS
    }

    fn submit(&mut self, ctx: RequestContext, _payload: Vec<HostTensor>) -> BackendReply {
        self.submits += 1;
        let shard = ctx.tenant % SHARDS;
        let pos = self.counts[shard];
        if pos >= CAP_PER_TICK {
            return BackendReply::Ready(Err(Reject::Overloaded));
        }
        self.counts[shard] += 1;
        self.accepted += 1;
        let latency_s = 0.0005 + 0.004 * (pos + 1) as f64 / CAP_PER_TICK as f64;
        BackendReply::Ready(Ok(InferenceResponse {
            id: self.accepted,
            tenant: ctx.tenant,
            output: HostTensor { shape: vec![1], data: vec![0.0] },
            latency_s,
            service_s: latency_s,
            fused_r: 1,
            trace_id: ctx.trace_id,
        }))
    }
}

/// Tenant `i`'s isolation class. Decorrelated from `i % SHARDS` (the
/// shard route) so every shard carries the same class mix.
fn class_of(i: usize) -> IsolationClass {
    match (i / SHARDS) % 4 {
        0 => IsolationClass::Premium,
        3 => IsolationClass::Batch,
        _ => IsolationClass::Standard,
    }
}

fn gateway_config() -> GatewayConfig {
    // Aggregate sustained rate = RATE_OVER_CAP x capacity, split across
    // tenants in proportion to their class rate multiplier.
    let mult_sum: f64 = (0..N_TENANTS).map(|i| class_of(i).rate_mult()).sum();
    let base_rate = RATE_OVER_CAP * CAP_RPS / mult_sum;
    GatewayConfig {
        rate: base_rate,
        burst: 4.0,
        // 64-outcome window: the 1x shard-arrival jitter (~1/6 overload
        // fraction) can never cluster to 50% of a window this long, while
        // the 100x burst flood fills it with overloads inside one tick.
        breaker_window: 64,
        breaker_threshold: 0.5,
        breaker_cooldown_ms: 25.0,
        half_open_probes: 3,
        tenants: (0..N_TENANTS)
            .map(|i| GatewayTenant {
                api_key: format!("key-{i}"),
                tenant: i,
                class: class_of(i),
            })
            .collect(),
        ..GatewayConfig::default()
    }
}

/// The offered-load tenant sequence: each tenant appears in proportion
/// to its sustainable share (class rate multiplier), deterministically
/// shuffled, cycled for the whole run. At 1x this offers every tenant
/// slightly LESS than its own token rate — the no-shedding baseline.
fn arrival_sequence() -> Vec<u32> {
    let mut seq = Vec::new();
    for i in 0..N_TENANTS {
        // 4x the multiplier -> integer copies: premium 16, standard 4,
        // batch 1.
        let copies = (class_of(i).rate_mult() * 4.0).round() as usize;
        seq.extend(std::iter::repeat(i as u32).take(copies));
    }
    let mut rng = Rng::new(SEED);
    rng.shuffle(&mut seq);
    seq
}

struct SweepResult {
    offered: u64,
    completed: u64,
    rate_limited: u64,
    breaker_shed: u64,
    backend_rejects: u64,
    trips: Vec<u64>,
    all_closed_at_end: bool,
    latencies: Vec<f64>,
}

impl SweepResult {
    fn goodput_rps(&self) -> f64 {
        self.completed as f64 / (HORIZON_TICKS as f64 * TICK_S)
    }
}

/// Run the full admission stack for `HORIZON_TICKS` of virtual time at
/// `factor` x capacity offered load.
fn run(factor: f64, keys: &[String], seq: &[u32]) -> SweepResult {
    let base = Instant::now();
    let mut gateway = Gateway::new(&gateway_config(), SimShards::new());
    let per_tick = (factor * CAP_RPS * TICK_S).round() as usize;
    let mut cursor = 0usize;
    let mut res = SweepResult {
        offered: 0,
        completed: 0,
        rate_limited: 0,
        breaker_shed: 0,
        backend_rejects: 0,
        trips: Vec::new(),
        all_closed_at_end: false,
        latencies: Vec::new(),
    };
    for tick in 0..HORIZON_TICKS {
        gateway.backend_mut().next_tick();
        let t0 = tick as f64 * TICK_S;
        for j in 0..per_tick {
            // Arrivals spread uniformly inside the tick.
            let now = base
                + Duration::from_secs_f64(t0 + TICK_S * j as f64 / per_tick.max(1) as f64);
            let tenant = seq[cursor] as usize;
            cursor = (cursor + 1) % seq.len();
            res.offered += 1;
            let wire = WireRequest {
                api_key: &keys[tenant],
                budget_ms: Some(BUDGET_MS),
                priority: None,
                trace_id: res.offered,
            };
            match gateway.admit(&wire, Vec::new(), now) {
                Ok(ticket) => {
                    if let Ok(r) = gateway.wait(ticket, now) {
                        res.completed += 1;
                        res.latencies.push(r.latency_s);
                    } else {
                        unreachable!("sim backend replies synchronously");
                    }
                }
                Err(Reject::Overloaded) => {} // counted via stats below
                Err(Reject::RateLimited { .. }) => {}
                Err(Reject::BreakerOpen { .. }) => {}
                Err(other) => panic!("unexpected rejection: {other}"),
            }
        }
    }
    let gstats = gateway.stats();
    res.rate_limited = gstats.rate_limited;
    res.breaker_shed = gstats.breaker_shed;
    res.backend_rejects = gstats.backend_rejects;
    let end = base + Duration::from_secs_f64(HORIZON_TICKS as f64 * TICK_S);
    res.all_closed_at_end =
        (0..SHARDS).all(|d| gateway.breaker_state(d) == BreakerState::Closed);
    let j = gateway.status_json(end);
    if let Some(breakers) = j.get("breakers").and_then(stgpu::util::json::Json::as_arr) {
        res.trips = breakers
            .iter()
            .map(|b| {
                b.get("trips")
                    .and_then(stgpu::util::json::Json::as_f64)
                    .unwrap_or(0.0) as u64
            })
            .collect();
    }
    res.latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    res
}

fn main() {
    banner(
        "Figure 16: overload degradation through the gateway (1x/10x/100x sweep)",
        "goodput at 100x >= 0.8x capacity goodput, admitted p99 bounded, breakers trip once and recover",
    );

    let keys: Vec<String> = (0..N_TENANTS).map(|i| format!("key-{i}")).collect();
    let seq = arrival_sequence();

    let factors = [1.0, 10.0, 100.0];
    let results: Vec<SweepResult> = factors.iter().map(|&f| run(f, &keys, &seq)).collect();

    let mut table = Table::new(&[
        "load",
        "offered",
        "completed",
        "goodput_rps",
        "rate_limited",
        "breaker_shed",
        "backend_rejects",
        "trips",
        "p50_ms",
        "p99_ms",
    ]);
    for (f, r) in factors.iter().zip(&results) {
        table.row(&[
            format!("{f}x"),
            r.offered.to_string(),
            r.completed.to_string(),
            format!("{:.0}", r.goodput_rps()),
            r.rate_limited.to_string(),
            r.breaker_shed.to_string(),
            r.backend_rejects.to_string(),
            r.trips.iter().sum::<u64>().to_string(),
            format!("{:.2}", stats::percentile_sorted(&r.latencies, 50.0) * 1e3),
            format!("{:.2}", stats::percentile_sorted(&r.latencies, 99.0) * 1e3),
        ]);
    }
    table.emit("fig16_overload_degradation");

    let g1 = results[0].goodput_rps();
    let g100 = results[2].goodput_rps();
    let retention = g100 / g1.max(1e-9);
    let p99_100 = stats::percentile_sorted(&results[2].latencies, 99.0);

    // 1x: the no-overload baseline — offered below every token rate, so
    // nothing is rate limited and no breaker ever trips.
    assert_eq!(results[0].rate_limited, 0, "1x load must not be rate limited");
    assert_eq!(
        results[0].trips.iter().sum::<u64>(),
        0,
        "1x load must not trip breakers"
    );
    // The 1x trace is clumpy (shuffled weighted round-robin), so a shard
    // occasionally sees more than its per-tick capacity; ~0.75-0.85x of
    // ideal capacity is the expected realized baseline.
    assert!(
        g1 >= 0.7 * CAP_RPS,
        "1x goodput should be near capacity: {g1:.0} vs {CAP_RPS:.0} rps"
    );
    // 100x: the headline claim — goodput holds within 20% of capacity
    // goodput while 99% of the offered load is shed at the gateway.
    assert!(
        retention >= 0.8,
        "goodput at 100x must be >= 0.8x capacity goodput: {g100:.0} vs {g1:.0} rps ({retention:.3}x)"
    );
    assert!(
        p99_100 <= 0.010,
        "admitted p99 must stay bounded under 100x overload: {p99_100:.4}s"
    );
    for (d, &t) in results[2].trips.iter().enumerate() {
        assert!(
            t >= 1,
            "shard {d} breaker must trip on the 100x burst-credit flood"
        );
    }
    assert!(
        results[2].breaker_shed > 0,
        "open breakers must shed at the gateway"
    );
    assert!(
        results[2].all_closed_at_end,
        "every breaker must probe back to closed by the end of the run"
    );
    assert!(
        results[2].rate_limited > 50 * results[2].backend_rejects.max(1),
        "at 100x the overwhelming majority of shed work must die at the \
         token bucket, not reach the backend: {} rate-limited vs {} backend rejects",
        results[2].rate_limited,
        results[2].backend_rejects
    );

    println!(
        "shape check: capacity {CAP_RPS:.0} rps; goodput {g1:.0} / {:.0} / {g100:.0} rps \
         at 1x/10x/100x ({retention:.3}x retention at 100x); \
         100x sheds {} rate-limited + {} breaker-shed + {} backend rejects; \
         trips per shard {:?}; p99 {:.2} ms.",
        results[1].goodput_rps(),
        results[2].rate_limited,
        results[2].breaker_shed,
        results[2].backend_rejects,
        results[2].trips,
        p99_100 * 1e3,
    );

    BenchJson::new("fig16_overload_degradation")
        .throughput(g100)
        .slo_attainment(retention.min(1.0))
        .p50_s(stats::percentile_sorted(&results[2].latencies, 50.0))
        .p99_s(p99_100)
        .scale(SHARDS as f64)
        .write();
}
