//! Tier-1 integration tests for the cluster tier's determinism contract:
//! a parallel (worker-pool) cluster run must produce a decision journal
//! that is *bitwise identical* to a serial re-execution of the same
//! configuration — including under hotspot-driven tenant migration and a
//! mid-run node kill/rejoin — and `replay_journal` must prove it after a
//! round trip through the on-disk framing.

use stgpu::coordinator::cluster::{ClusterOpts, FaultOpts, HotspotOpts};
use stgpu::coordinator::{replay_journal, run_cluster, Journal};
use stgpu::util::json::Json;

/// The ISSUE 8 acceptance check: replay of a 4-node parallel journal
/// yields a bitwise-identical journal from the serial path.
#[test]
fn four_node_parallel_journal_replays_bitwise_identically() {
    let mut opts = ClusterOpts::demo(4);
    opts.rounds = 80;
    let parallel = run_cluster(&opts, true).expect("parallel run");
    let serial = run_cluster(&opts, false).expect("serial run");
    assert_eq!(
        parallel.journal.digest(),
        serial.journal.digest(),
        "parallel and serial digests diverged"
    );
    assert_eq!(parallel.journal.bytes(), serial.journal.bytes());

    let out = replay_journal(&parallel.journal).expect("replay");
    assert!(out.matches, "replay mismatch: {} vs {}", out.original, out.replayed);
    assert_eq!(out.nodes, 4);
}

#[test]
fn journal_survives_the_on_disk_round_trip() {
    let mut opts = ClusterOpts::demo(2);
    opts.rounds = 40;
    let report = run_cluster(&opts, true).expect("run");
    let dir = std::env::temp_dir().join(format!("stgpu_cluster_replay_{}", std::process::id()));
    let path = dir.join("journal.bin");
    report.journal.write_to(&path).expect("write journal");
    let back = Journal::read_from(&path).expect("read journal");
    assert_eq!(back.digest(), report.journal.digest());
    assert_eq!(back.bytes(), report.journal.bytes());
    assert_eq!(back.records().len(), report.journal.records().len());
    let out = replay_journal(&back).expect("replay from disk");
    assert!(out.matches);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Migration and fault events land in the journal as typed records, and
/// the run conserves requests even while tenants are in transfer.
#[test]
fn migration_and_fault_records_replay_and_conserve() {
    let mut opts = ClusterOpts::demo(3);
    opts.rounds = 90;
    // A near-zero utilization threshold forces the hotspot detector to
    // fire as soon as it sustains; the hotspot window gives it material.
    opts.migrate_util = 1e-9;
    opts.migrate_sustain = 2;
    opts.hotspot = Some(HotspotOpts { node: 0, from_round: 10, to_round: 50, factor: 4.0 });
    opts.fault = Some(FaultOpts { node: 1, kill_round: 30, rejoin_round: 60 });
    let parallel = run_cluster(&opts, true).expect("parallel run");
    assert!(parallel.migrations >= 1, "hotspot never fired a migration");
    assert_eq!(parallel.node_downs, 1);
    assert_eq!(parallel.node_ups, 1);
    assert!(parallel.conservation_ok(), "request conservation violated");

    let kinds: Vec<&str> = parallel
        .journal
        .records()
        .iter()
        .filter_map(|r| r.get("kind").and_then(Json::as_str))
        .collect();
    assert!(kinds.contains(&"migrate"));
    assert!(kinds.contains(&"node_down"));
    assert!(kinds.contains(&"node_up"));
    assert_eq!(kinds.first(), Some(&"header"));
    assert_eq!(kinds.last(), Some(&"summary"));

    let out = replay_journal(&parallel.journal).expect("replay");
    assert!(
        out.matches,
        "journal with migration + kill/rejoin must still replay bitwise: {} vs {}",
        out.original, out.replayed
    );
}

/// A corrupted journal is rejected by the frame checksum, not silently
/// replayed.
#[test]
fn corrupted_journal_fails_decode() {
    let mut opts = ClusterOpts::demo(2);
    opts.rounds = 20;
    let report = run_cluster(&opts, false).expect("run");
    let mut bytes = report.journal.bytes().to_vec();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    let err = Journal::decode(&bytes).expect_err("corruption must not decode");
    assert!(
        err.contains("checksum mismatch") || err.contains("truncated"),
        "unexpected error: {err}"
    );
}
