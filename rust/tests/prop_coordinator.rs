//! Property tests over coordinator invariants: batching, routing, queueing
//! and monitor state machines. These run against the pure logic (no PJRT),
//! so thousands of cases are cheap.

use std::time::Instant;

use stgpu::coordinator::batcher::{DynamicBatcher, PaddingPolicy};
use stgpu::coordinator::monitor::{MonitorConfig, SloMonitor};
use stgpu::coordinator::queue::QueueSet;
use stgpu::coordinator::request::{InferenceRequest, Priority, ShapeClass};
use stgpu::coordinator::scheduler::{
    launch_weight, make_scheduler, Scheduler, SpaceTimeSched,
};
use stgpu::coordinator::tenant::TenantRegistry;
use stgpu::config::SchedulerKind;
use stgpu::util::prng::Rng;
use stgpu::util::prop::{check, run_prop, sized};

const SHAPES: [(usize, usize, usize); 4] =
    [(512, 1, 512), (256, 128, 1152), (256, 256, 256), (64, 32, 48)];

fn rand_class(rng: &mut Rng) -> ShapeClass {
    let (m, n, k) = SHAPES[rng.gen_range(SHAPES.len() as u64) as usize];
    ShapeClass::batched_gemm(m, n, k)
}

fn rand_requests(rng: &mut Rng, n_tenants: usize, max: usize) -> Vec<InferenceRequest> {
    let n = sized(rng, max as u64) as usize;
    (0..n)
        .map(|i| InferenceRequest {
            id: i as u64,
            tenant: rng.gen_range(n_tenants as u64) as usize,
            class: rand_class(rng),
            payload: vec![],
            arrived: Instant::now(),
            deadline: Instant::now(),
            priority: Priority::Normal,
            trace_id: 0,
        })
        .collect()
}

fn buckets() -> Vec<usize> {
    vec![1, 2, 4, 8, 16, 32, 64]
}

// ---------------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_requests() {
    check("batcher conserves requests", 0xB0, |rng| {
        let max_batch = 1 + sized(rng, 64) as usize;
        let mut b = DynamicBatcher::new(buckets(), max_batch);
        let reqs = rand_requests(rng, 8, 200);
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let launches = b.plan(reqs);
        let mut out: Vec<u64> = launches
            .iter()
            .flat_map(|l| l.entries.iter().map(|e| e.id))
            .collect();
        out.sort_unstable();
        let mut want = ids;
        want.sort_unstable();
        assert_eq!(out, want, "every request appears in exactly one launch");
    });
}

#[test]
fn prop_batcher_never_mixes_classes() {
    check("no cross-class fusion", 0xB1, |rng| {
        let mut b = DynamicBatcher::new(buckets(), 1 + sized(rng, 64) as usize);
        for l in b.plan(rand_requests(rng, 8, 200)) {
            assert!(l.entries.iter().all(|e| e.class == l.class));
        }
    });
}

#[test]
fn prop_batcher_respects_max_batch_and_buckets() {
    check("launch sizes legal", 0xB2, |rng| {
        let max_batch = 1 + sized(rng, 64) as usize;
        let mut b = DynamicBatcher::new(buckets(), max_batch);
        for l in b.plan(rand_requests(rng, 8, 200)) {
            assert!(!l.entries.is_empty());
            assert!(l.entries.len() <= max_batch);
            assert!(l.entries.len() <= l.r_bucket);
            assert!(buckets().contains(&l.r_bucket), "bucket {}", l.r_bucket);
            // Round-up is tight: the next smaller bucket wouldn't fit.
            let smaller: Vec<usize> =
                buckets().into_iter().filter(|&x| x < l.r_bucket).collect();
            if let Some(&prev) = smaller.last() {
                assert!(
                    l.entries.len() > prev,
                    "{} problems should not use bucket {} (prev {})",
                    l.entries.len(),
                    l.r_bucket,
                    prev
                );
            }
        }
    });
}

#[test]
fn prop_batcher_padding_bounded_by_2x() {
    // Powers-of-two buckets bound padding waste to < 50% of lanes.
    check("padding waste < 0.5", 0xB3, |rng| {
        let mut b = DynamicBatcher::new(buckets(), 64);
        let reqs = rand_requests(rng, 8, 300);
        if reqs.is_empty() {
            return;
        }
        b.plan(reqs);
        assert!(
            b.stats.padding_waste() < 0.5,
            "waste {}",
            b.stats.padding_waste()
        );
    });
}

#[test]
fn prop_split_exact_with_non_power_of_two_buckets() {
    // SplitExact is documented for arbitrary bucket sets, not just the
    // default powers of two: greedy largest-first decomposition, where only
    // the FINAL fragment of a chunk may carry padding. Check request
    // conservation, per-tenant FIFO, legal launch sizes, and padding
    // accounting against randomized non-power-of-two bucket sets.
    check("split-exact / non-po2 buckets", 0xB4, |rng| {
        // 2-5 distinct buckets drawn from [1, 24]; ensure none is a power
        // of two by preferring odd values (1 allowed — it is the floor the
        // greedy loop falls back to).
        let n_buckets = 2 + rng.gen_range(4) as usize;
        let mut buckets: Vec<usize> = (0..n_buckets)
            .map(|_| 1 + 2 * rng.gen_range(12) as usize) // odd in [1, 23]
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        let max_batch = 1 + sized(rng, 64) as usize;
        let mut b = DynamicBatcher::with_policy(
            buckets.clone(),
            max_batch,
            PaddingPolicy::SplitExact,
        );
        let reqs = rand_requests(rng, 6, 200);
        let submitted: Vec<(ShapeClass, u64)> =
            reqs.iter().map(|r| (r.class, r.id)).collect();
        let mut want_ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let launches = b.plan(reqs);

        // Conservation: every request appears exactly once.
        let mut got_ids: Vec<u64> = launches
            .iter()
            .flat_map(|l| l.entries.iter().map(|e| e.id))
            .collect();
        got_ids.sort_unstable();
        want_ids.sort_unstable();
        assert_eq!(got_ids, want_ids, "buckets {buckets:?}");

        // Per-(tenant, class) FIFO across the whole plan.
        let mut last: std::collections::HashMap<(usize, ShapeClass), u64> =
            std::collections::HashMap::new();
        for l in &launches {
            for e in &l.entries {
                if let Some(&prev) = last.get(&(e.tenant, e.class)) {
                    assert!(
                        e.id > prev,
                        "tenant {} ids out of order with buckets {buckets:?}",
                        e.tenant
                    );
                }
                last.insert((e.tenant, e.class), e.id);
            }
        }

        // Launch sizes legal: non-empty, within cap, within the chosen
        // bucket, and the bucket is a real one.
        for l in &launches {
            assert!(!l.entries.is_empty());
            assert!(l.entries.len() <= max_batch);
            assert!(l.entries.len() <= l.r_bucket);
            assert!(buckets.contains(&l.r_bucket), "bucket {}", l.r_bucket);
        }

        // Padding accounting: stats tie out with per-launch padded lanes.
        let lanes: u64 = launches.iter().map(|l| l.r_bucket as u64).sum();
        let problems: u64 = launches.iter().map(|l| l.entries.len() as u64).sum();
        assert_eq!(b.stats.problems, problems);
        assert_eq!(b.stats.padded_lanes, lanes - problems);
        assert_eq!(b.stats.launches, launches.len() as u64);

        // Structural oracle: re-run the documented greedy decomposition
        // (classes in sorted order, chunks of min(max_batch, largest),
        // largest-bucket-first fragments) and require the exact same
        // (class, size, bucket) launch sequence. This pins the "padding
        // only on a chunk's final fragment" guarantee: every non-final
        // fragment the oracle emits is exactly bucket-sized.
        let chunk_cap = max_batch.min(*buckets.last().unwrap());
        let bucket_for =
            |n: usize| buckets.iter().copied().find(|&b| b >= n).unwrap();
        let mut classes: Vec<ShapeClass> = submitted.iter().map(|(c, _)| *c).collect();
        classes.sort_unstable();
        classes.dedup();
        let mut expected: Vec<(ShapeClass, usize, usize)> = Vec::new();
        for class in classes {
            let n_class = submitted.iter().filter(|(c, _)| *c == class).count();
            let mut remaining = n_class;
            while remaining > 0 {
                let mut rest = remaining.min(chunk_cap);
                remaining -= rest;
                while rest > 0 {
                    let take = buckets
                        .iter()
                        .rev()
                        .copied()
                        .find(|&b| b <= rest)
                        .unwrap_or(buckets[0])
                        .min(rest);
                    expected.push((class, take, bucket_for(take)));
                    if take < rest {
                        // Non-final fragment: must be an exact bucket.
                        assert!(buckets.contains(&take));
                    }
                    rest -= take;
                }
            }
        }
        let actual: Vec<(ShapeClass, usize, usize)> = launches
            .iter()
            .map(|l| (l.class, l.entries.len(), l.r_bucket))
            .collect();
        assert_eq!(actual, expected, "buckets {buckets:?} max_batch {max_batch}");
    });
}

// ---------------------------------------------------------------------------
// Scheduler invariants
// ---------------------------------------------------------------------------

fn fill_queues(rng: &mut Rng, n_tenants: usize, max_per: usize) -> (QueueSet, usize) {
    let mut q = QueueSet::new(n_tenants, 10_000);
    let mut total = 0;
    let mut id = 0u64;
    for t in 0..n_tenants {
        let n = rng.gen_range(max_per as u64 + 1) as usize;
        for _ in 0..n {
            q.push(InferenceRequest {
                id,
                tenant: t,
                class: rand_class(rng),
                payload: vec![],
                arrived: Instant::now(),
                deadline: Instant::now(),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
            id += 1;
            total += 1;
        }
    }
    (q, total)
}

#[test]
fn prop_all_schedulers_drain_everything() {
    for kind in [
        SchedulerKind::Exclusive,
        SchedulerKind::TimeMux,
        SchedulerKind::SpaceMux,
        SchedulerKind::SpaceTime,
    ] {
        run_prop(&format!("{kind:?} drains"), 0xC0, 64, |rng| {
            let n_tenants = 1 + rng.gen_range(8) as usize;
            let (mut q, total) = fill_queues(rng, n_tenants, 30);
            let mut s = make_scheduler(kind, buckets(), 16);
            let mut served = 0;
            let mut rounds = 0;
            while !q.is_empty() {
                let plan = s.plan_round(&mut q);
                served += plan.drained;
                rounds += 1;
                assert!(
                    rounds <= total.max(1) * 2 + 8,
                    "{}: too many rounds ({rounds}) for {total} requests",
                    s.label()
                );
                assert_eq!(
                    plan.drained,
                    plan.launches.iter().map(|l| l.entries.len()).sum::<usize>()
                );
            }
            assert_eq!(served, total);
        });
    }
}

#[test]
fn prop_timemux_launches_are_singletons() {
    check("time-mux singletons", 0xC1, |rng| {
        let (mut q, _) = fill_queues(rng, 4, 20);
        let mut s = make_scheduler(SchedulerKind::TimeMux, buckets(), 16);
        while !q.is_empty() {
            for l in s.plan_round(&mut q).launches {
                assert_eq!(l.entries.len(), 1);
                assert_eq!(l.r_bucket, 1);
            }
        }
    });
}

#[test]
fn prop_exclusive_never_mixes_tenants() {
    check("exclusive single-tenant launches", 0xC2, |rng| {
        let (mut q, _) = fill_queues(rng, 6, 20);
        let mut s = make_scheduler(SchedulerKind::Exclusive, buckets(), 16);
        while !q.is_empty() {
            for l in s.plan_round(&mut q).launches {
                let t0 = l.entries[0].tenant;
                assert!(l.entries.iter().all(|e| e.tenant == t0));
            }
        }
    });
}

#[test]
fn prop_spacetime_fifo_per_tenant_and_class() {
    // FIFO holds per (tenant, shape class): a tenant's same-class requests
    // complete in submission order. Cross-class order within one round is
    // concurrent by design (launches are independent super-kernels), and
    // lane order within a launch is canonicalized for fusion-cache reuse.
    check("space-time preserves per-(tenant,class) FIFO", 0xC3, |rng| {
        let (mut q, _) = fill_queues(rng, 5, 30);
        let mut s = make_scheduler(SchedulerKind::SpaceTime, buckets(), 16);
        let mut last_seen: std::collections::HashMap<(usize, ShapeClass), u64> =
            std::collections::HashMap::new();
        while !q.is_empty() {
            for l in s.plan_round(&mut q).launches {
                for e in &l.entries {
                    if let Some(&prev) = last_seen.get(&(e.tenant, e.class)) {
                        assert!(
                            e.id > prev,
                            "tenant {} class {} ids out of order: {} after {}",
                            e.tenant,
                            e.class,
                            e.id,
                            prev
                        );
                    }
                    last_seen.insert((e.tenant, e.class), e.id);
                }
            }
        }
    });
}

#[test]
fn prop_spacetime_single_class_fills_before_splitting() {
    // With one shape class and <= max_batch total, everything lands in one
    // launch — the paper's "merge all queued problems" roofline case.
    check("space-time merges all queued", 0xC4, |rng| {
        let n_tenants = 1 + rng.gen_range(6) as usize;
        let class = ShapeClass::batched_gemm(256, 256, 256);
        let mut q = QueueSet::new(n_tenants, 1000);
        let total = 1 + sized(rng, 64) as usize;
        for i in 0..total {
            q.push(InferenceRequest {
                id: i as u64,
                tenant: i % n_tenants,
                class,
                payload: vec![],
                arrived: Instant::now(),
                deadline: Instant::now(),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .unwrap();
        }
        let mut s = make_scheduler(SchedulerKind::SpaceTime, buckets(), 64);
        let plan = s.plan_round(&mut q);
        assert_eq!(plan.launches.len(), 1, "total={total}");
        assert_eq!(plan.launches[0].entries.len(), total.min(64));
    });
}

// ---------------------------------------------------------------------------
// Spatial-lane invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_spacetime_lane_assignment_invariants() {
    // Across random workloads and lane counts: every planned launch lands
    // on exactly one lane; lane ids are in range; the greedy balancer's
    // worst lane stays within the list-scheduling bound
    // (total/L + max single weight); requests are conserved.
    check("space-time lane assignment", 0xC5, |rng| {
        let lanes = 1 + rng.gen_range(4) as usize; // 1..=4
        let n_tenants = 1 + rng.gen_range(6) as usize;
        let (mut q, total) = fill_queues(rng, n_tenants, 30);
        let mut s = SpaceTimeSched::new(buckets(), 16).spatial_lanes(lanes, None);
        let mut served = 0usize;
        while !q.is_empty() {
            let plan = s.plan_round(&mut q);
            served += plan.drained;
            if plan.n_lanes > 1 {
                assert_eq!(
                    plan.lane_of.len(),
                    plan.launches.len(),
                    "every launch needs exactly one lane"
                );
            }
            assert!(plan.n_lanes <= lanes, "planned more lanes than configured");
            assert!(plan.n_lanes <= plan.launches.len().max(1));
            let n_lanes = plan.n_lanes.max(1);
            for i in 0..plan.launches.len() {
                assert!(plan.lane(i) < n_lanes, "lane id out of range");
            }
            let weights: Vec<f64> = plan.launches.iter().map(launch_weight).collect();
            let mut loads = vec![0.0f64; n_lanes];
            for (i, &w) in weights.iter().enumerate() {
                loads[plan.lane(i)] += w;
            }
            let total_w: f64 = weights.iter().sum();
            let max_w = weights.iter().cloned().fold(0.0, f64::max);
            let worst = loads.iter().cloned().fold(0.0, f64::max);
            assert!(
                worst <= total_w / n_lanes as f64 + max_w + 1e-9,
                "greedy makespan bound violated: worst {worst} total {total_w} \
                 max {max_w} lanes {n_lanes}"
            );
        }
        assert_eq!(served, total);
    });
}

#[test]
fn prop_baseline_plans_are_always_single_lane() {
    for kind in [
        SchedulerKind::Exclusive,
        SchedulerKind::TimeMux,
        SchedulerKind::SpaceMux,
    ] {
        run_prop(&format!("{kind:?} single-lane"), 0xC6, 64, |rng| {
            let (mut q, _) = fill_queues(rng, 5, 20);
            let mut s = make_scheduler(kind, buckets(), 16);
            while !q.is_empty() {
                let plan = s.plan_round(&mut q);
                assert!(plan.n_lanes <= 1, "{} planned {} lanes", s.label(), plan.n_lanes);
                assert!(plan.lane_of.is_empty(), "{} assigned lanes", s.label());
                assert!(plan.lanes_used() <= 1);
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Queue + monitor invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_queue_depth_is_hard_bound() {
    check("queue depth bound", 0xD0, |rng| {
        let depth = 1 + sized(rng, 64) as usize;
        let mut q = QueueSet::new(1, depth);
        let n = sized(rng, 200) as usize;
        let mut accepted = 0;
        for i in 0..n {
            let r = InferenceRequest {
                id: i as u64,
                tenant: 0,
                class: rand_class(rng),
                payload: vec![],
                arrived: Instant::now(),
                deadline: Instant::now(),
                priority: Priority::Normal,
                trace_id: 0,
            };
            if q.push(r).is_ok() {
                accepted += 1;
            }
            assert!(q.total_pending() <= depth);
        }
        assert_eq!(accepted, n.min(depth));
    });
}

#[test]
fn prop_monitor_evicts_at_most_the_stragglers() {
    run_prop("monitor evicts only stragglers", 0xD1, 128, |rng| {
        let n = 3 + rng.gen_range(6) as usize;
        let n_stragglers = rng.gen_range((n as u64 - 1) / 2) as usize; // minority
        let mut reg = TenantRegistry::new();
        for i in 0..n {
            reg.register(&format!("t{i}"), "sgemm:64x64x64", 1000.0, i as u64)
                .unwrap();
        }
        let mut mon = SloMonitor::new(
            MonitorConfig { strikes: 2, ..Default::default() },
            &reg,
        );
        let slow_factor = 1.5 + rng.next_f64() * 3.0;
        for _round in 0..40 {
            for t in 0..n {
                let base = 1e-3 * (1.0 + 0.01 * rng.next_f64()); // small jitter
                let lat = if t < n_stragglers { base * slow_factor } else { base };
                mon.observe(t, lat);
            }
        }
        for _ in 0..4 {
            mon.check(&mut reg);
        }
        // Every straggler evicted, no healthy tenant evicted.
        for t in 0..n {
            let evicted = !reg.get(t).unwrap().is_servable();
            if t < n_stragglers {
                assert!(evicted, "straggler {t} (x{slow_factor:.2}) not evicted");
            } else {
                assert!(!evicted, "healthy tenant {t} wrongly evicted");
            }
        }
    });
}
