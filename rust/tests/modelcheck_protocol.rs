//! Schedule-exhaustive model checking of the lane pipeline's
//! synchronization protocol.
//!
//! These tests instantiate the SAME generic [`LaneProtocol`] the
//! production [`stgpu::coordinator::LanePool`] wraps — but under
//! [`ModelEnv`], where every channel operation is a decision point for
//! the DFS schedule explorer in [`stgpu::util::modelcheck`]. Each test
//! asserts its invariant inline; [`explore`] runs the body under every
//! interleaving (up to the stated preemption bound) and reports the
//! explored-schedule count (run with `--nocapture` to see it — the CI
//! model-check job does).
//!
//! The `mutation_*` tests re-introduce known-bad protocol variants and
//! assert the checker CATCHES them — the tooling's own regression suite:
//! * a resize that abandons a retired lane's queued items (vs. the trunk
//!   retire-by-sender-drop, which drains),
//! * a snapshot mirror published as independent words with no version
//!   counter (vs. the trunk seqlock publish in
//!   `coordinator::driver::SnapshotMirror`),
//! * a driver that over-collects — the stuck-submitter deadlock.

use std::sync::{Arc, Mutex, PoisonError};

use stgpu::coordinator::protocol::{
    ItemRunner, LaneProtocol, LaneTagged, ProtoPayload, ProtoReceiver, ProtoSender, SyncEnv,
};
use stgpu::util::modelcheck::{explore, CheckOpts, ModelEnv};

// ---------------------------------------------------------------------------
// Model payloads: round-tagged items small enough to fingerprint exactly.
// ---------------------------------------------------------------------------

struct MItem {
    id: u64,
    lane: usize,
}

impl ProtoPayload for MItem {
    fn fingerprint(&self) -> u64 {
        self.id
    }
}

impl LaneTagged for MItem {
    fn lane(&self) -> usize {
        self.lane
    }
    fn set_lane(&mut self, lane: usize) {
        self.lane = lane;
    }
}

struct MDone {
    id: u64,
}

impl ProtoPayload for MDone {
    fn fingerprint(&self) -> u64 {
        self.id
    }
}

/// The model runner: yields once mid-execution so the explorer can park a
/// worker *between* taking an item and reporting it — the window where
/// real executors spend their time and where lost-completion bugs hide.
struct MRunner;

impl ItemRunner<MItem, MDone> for MRunner {
    fn run(&self, item: MItem) -> MDone {
        ModelEnv::yield_now();
        MDone { id: item.id }
    }
}

fn model_pool(lanes: usize) -> LaneProtocol<ModelEnv, MItem, MDone> {
    LaneProtocol::new(lanes, Arc::new(MRunner))
}

/// Mark `id` collected exactly once in `seen`.
fn mark(seen: &mut [bool], id: u64) {
    let slot = &mut seen[id as usize];
    assert!(!*slot, "completion {id} surfaced twice");
    *slot = true;
}

// ---------------------------------------------------------------------------
// Trunk protocol checks (must pass on every schedule)
// ---------------------------------------------------------------------------

#[test]
fn model_single_lane_dispatch_collect_fully_exhaustive() {
    // Two threads (driver + one worker), NO preemption bound: every
    // interleaving of dispatch/execute/collect/shutdown, period.
    let opts = CheckOpts { max_preemptions: usize::MAX, ..CheckOpts::default() };
    let stats = explore("single-lane", opts, || {
        let mut pool = model_pool(1);
        pool.dispatch(MItem { id: 0, lane: 0 });
        pool.dispatch(MItem { id: 1, lane: 0 });
        let mut seen = [false; 2];
        for _ in 0..2 {
            let d = pool.collect().expect("worker alive");
            mark(&mut seen, d.id);
        }
        assert!(seen.iter().all(|&s| s), "a completion was lost");
        assert_eq!(pool.in_flight(), 0);
        let leftover = pool.shutdown_drain();
        assert!(leftover.is_empty(), "drain after full collect must be empty");
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("single-lane dispatch/collect: {stats}");
    assert!(!stats.truncated, "exploration must be exhaustive");
    assert!(stats.schedules > 1);
}

#[test]
fn model_two_lanes_conserve_round_tagged_items() {
    // Three threads; preemption-bounded (CHESS-style: almost all real
    // concurrency bugs surface within two preemptions).
    let opts = CheckOpts { max_preemptions: 1, ..CheckOpts::default() };
    let stats = explore("two-lanes", opts, || {
        let mut pool = model_pool(2);
        pool.dispatch(MItem { id: 0, lane: 0 });
        pool.dispatch(MItem { id: 1, lane: 1 });
        let mut seen = [false; 2];
        for _ in 0..2 {
            let d = pool.collect().expect("workers alive");
            mark(&mut seen, d.id);
        }
        assert!(seen.iter().all(|&s| s), "a lane lost its item");
        assert_eq!(pool.in_flight(), 0);
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("two-lane conservation: {stats}");
    assert!(!stats.truncated);
    assert!(stats.schedules > 1);
}

#[test]
fn model_resize_retire_drains_queued_items() {
    // The resize protocol: shrink while the retired lane still owes a
    // queued item. Trunk retires by dropping the lane's sender, so the
    // worker drains its queue before exiting — no schedule may lose the
    // item (contrast `mutation_retire_abandoning_queue_is_caught`).
    let opts = CheckOpts { max_preemptions: 1, ..CheckOpts::default() };
    let stats = explore("resize-retire", opts, || {
        let mut pool = model_pool(2);
        pool.dispatch(MItem { id: 0, lane: 1 });
        pool.dispatch(MItem { id: 1, lane: 1 });
        pool.resize(1); // retire lane 1 with items possibly still queued
        pool.dispatch(MItem { id: 2, lane: 1 }); // clamps onto lane 0
        let mut seen = [false; 3];
        for _ in 0..3 {
            let d = pool.collect().expect("workers alive");
            mark(&mut seen, d.id);
        }
        assert!(
            seen.iter().all(|&s| s),
            "resize dropped a retired lane's queued item"
        );
        assert_eq!(pool.lanes(), 1);
        assert_eq!(pool.in_flight(), 0);
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("resize retire/drain: {stats}");
    assert!(!stats.truncated);
}

#[test]
fn model_shutdown_drain_conserves_uncollected_completions() {
    // Shut down with work still queued/executing at every possible point:
    // collected + drained must equal dispatched on EVERY schedule.
    let opts = CheckOpts { max_preemptions: 2, ..CheckOpts::default() };
    let stats = explore("shutdown-drain", opts, || {
        let mut pool = model_pool(1);
        pool.dispatch(MItem { id: 0, lane: 0 });
        pool.dispatch(MItem { id: 1, lane: 0 });
        let mut seen = [false; 2];
        let d = pool.collect().expect("worker alive");
        mark(&mut seen, d.id);
        // Shutdown races the second item: it may be queued, executing, or
        // already completed — it must surface in the drain regardless.
        for d in pool.shutdown_drain() {
            mark(&mut seen, d.id);
        }
        assert!(
            seen.iter().all(|&s| s),
            "shutdown lost an in-flight completion"
        );
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("shutdown drain: {stats}");
    assert!(!stats.truncated);
}

// ---------------------------------------------------------------------------
// Mutation checks: known-bad variants the checker must catch
// ---------------------------------------------------------------------------

/// Control-plane message for the hand-rolled buggy pool below.
enum Msg {
    Item(u64),
    /// The mutation: an in-band "retire now" sentinel.
    Retire,
}

impl ProtoPayload for Msg {
    fn fingerprint(&self) -> u64 {
        match self {
            Msg::Item(id) => *id,
            Msg::Retire => u64::MAX,
        }
    }
}

#[test]
fn mutation_retire_abandoning_queue_is_caught() {
    // Re-introduce the known-bad resize variant: retiring a lane via an
    // in-band sentinel that makes the worker exit IMMEDIATELY, abandoning
    // items queued behind it (trunk drops the sender instead, so the
    // worker drains first — see `model_resize_retire_drains_queued_items`
    // for the trunk twin passing this exact workload). The driver then
    // waits for a completion that can never arrive; the checker must
    // report the stuck submitter.
    let err = explore("buggy-retire", CheckOpts::default(), || {
        let (work_tx, work_rx) = ModelEnv::channel::<Msg>();
        let (done_tx, done_rx) = ModelEnv::channel::<Msg>();
        let done_keep = done_tx.clone(); // driver keeps the channel open (as the pool does)
        let w = ModelEnv::spawn("worker".into(), move || {
            while let Some(m) = work_rx.recv() {
                match m {
                    Msg::Item(id) => {
                        if done_tx.send(Msg::Item(id)).is_err() {
                            return;
                        }
                    }
                    // BUG: exit without draining the rest of the queue.
                    Msg::Retire => return,
                }
            }
        });
        let _ = work_tx.send(Msg::Item(1));
        let _ = work_tx.send(Msg::Retire);
        let _ = work_tx.send(Msg::Item(2)); // queued behind the sentinel: lost
        let _ = done_rx.recv().expect("first completion");
        let _ = done_rx.recv().expect("second completion"); // never arrives
        w.join();
        drop(done_keep);
    })
    .expect_err("the checker must catch the abandoned queue");
    assert!(err.message.contains("deadlock"), "got: {}", err.message);
    println!("buggy retire caught after {} schedule(s)", err.schedules);
}

#[test]
fn mutation_unversioned_mirror_publish_is_caught() {
    // Re-introduce the pre-seqlock SnapshotMirror bug: per-lane busy and
    // launch counts published as independent words. A reader landing
    // between the two writes observes a torn pair. The invariant below
    // (busy == launches * 10) mirrors the driver's "busy accrues with
    // each launch" relation.
    let err = explore("torn-mirror", CheckOpts::default(), || {
        let busy = Arc::new(Mutex::new(0u64));
        let launches = Arc::new(Mutex::new(0u64));
        let (b2, l2) = (busy.clone(), launches.clone());
        let writer = ModelEnv::spawn("writer".into(), move || {
            // BUG: two independent publishes with a schedulable window
            // between them (the yield models the instruction boundary).
            *b2.lock().unwrap_or_else(PoisonError::into_inner) += 10;
            ModelEnv::yield_now();
            *l2.lock().unwrap_or_else(PoisonError::into_inner) += 1;
        });
        let (b3, l3) = (busy.clone(), launches.clone());
        let reader = ModelEnv::spawn("reader".into(), move || {
            let l = *l3.lock().unwrap_or_else(PoisonError::into_inner);
            ModelEnv::yield_now();
            let b = *b3.lock().unwrap_or_else(PoisonError::into_inner);
            assert!(
                b == l * 10,
                "torn read: busy={b} launches={l} (unversioned publish)"
            );
        });
        writer.join();
        reader.join();
    })
    .expect_err("the checker must find the torn interleaving");
    assert!(err.message.contains("torn read"), "got: {}", err.message);
    println!("torn mirror caught after {} schedule(s)", err.schedules);
}

#[test]
fn model_seqlocked_mirror_publish_is_untearable() {
    // The trunk fix for the mutation above: publish under a version
    // counter (odd while writing, bumped even after), reader retries on a
    // version mismatch. On every schedule, any snapshot the reader
    // accepts is consistent. Bounded retries keep the model finite; a
    // reader that exhausts them simply skips (as a real sampler would).
    let opts = CheckOpts { max_preemptions: 2, ..CheckOpts::default() };
    let stats = explore("seqlock-mirror", opts, || {
        let seq = Arc::new(Mutex::new(0u64));
        let busy = Arc::new(Mutex::new(0u64));
        let launches = Arc::new(Mutex::new(0u64));
        let (s2, b2, l2) = (seq.clone(), busy.clone(), launches.clone());
        let writer = ModelEnv::spawn("writer".into(), move || {
            *s2.lock().unwrap_or_else(PoisonError::into_inner) = 1; // odd: write open
            ModelEnv::yield_now();
            *b2.lock().unwrap_or_else(PoisonError::into_inner) += 10;
            ModelEnv::yield_now();
            *l2.lock().unwrap_or_else(PoisonError::into_inner) += 1;
            ModelEnv::yield_now();
            *s2.lock().unwrap_or_else(PoisonError::into_inner) = 2; // even: publish
        });
        let (s3, b3, l3) = (seq.clone(), busy.clone(), launches.clone());
        let reader = ModelEnv::spawn("reader".into(), move || {
            for _ in 0..4 {
                let s1 = *s3.lock().unwrap_or_else(PoisonError::into_inner);
                ModelEnv::yield_now();
                if s1 % 2 == 1 {
                    continue; // write in progress
                }
                let l = *l3.lock().unwrap_or_else(PoisonError::into_inner);
                ModelEnv::yield_now();
                let b = *b3.lock().unwrap_or_else(PoisonError::into_inner);
                let s2 = *s3.lock().unwrap_or_else(PoisonError::into_inner);
                if s1 != s2 {
                    continue; // raced a writer: retry
                }
                assert!(b == l * 10, "seqlock let a torn pair through: {b} vs {l}");
                return;
            }
        });
        writer.join();
        reader.join();
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("seqlock mirror: {stats}");
    assert!(!stats.truncated);
}

#[test]
fn mutation_overcollect_is_caught_as_stuck_submitter() {
    // Re-introduce a driver bookkeeping bug: collecting more completions
    // than were dispatched. The completion channel stays open (the pool
    // keeps a sender for resize), so the extra collect blocks forever —
    // exactly the "stuck submitter" the deadlock detector exists for.
    let err = explore("overcollect", CheckOpts::default(), || {
        let mut pool = model_pool(1);
        pool.dispatch(MItem { id: 0, lane: 0 });
        let _ = pool.collect().expect("the real completion");
        let _ = pool.collect(); // BUG: nothing is in flight
    })
    .expect_err("the checker must catch the stuck submitter");
    assert!(err.message.contains("deadlock"), "got: {}", err.message);
    assert!(err.message.contains("recv"), "got: {}", err.message);
    println!("overcollect caught after {} schedule(s)", err.schedules);
}
