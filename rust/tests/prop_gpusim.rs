//! Property tests over the GPU simulator: conservation laws, cost-model
//! monotonicity, and policy-independent invariants. Pure logic — thousands
//! of randomized cases are cheap.

use stgpu::gpusim::cost::{exclusive_time, kernel_service_time, CostCtx};
use stgpu::gpusim::kernel::KernelDesc;
use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::util::prng::Rng;
use stgpu::util::prop::{check, run_prop, sized};
use stgpu::workload::sgemm_tenants;

fn rand_shape(rng: &mut Rng) -> GemmShape {
    GemmShape::new(
        1 + sized(rng, 1024) as u32,
        1 + sized(rng, 1024) as u32,
        1 + sized(rng, 2048) as u32,
    )
}

fn policies(rng: &mut Rng) -> Policy {
    match rng.gen_range(5) {
        0 => Policy::Exclusive,
        1 => Policy::TimeMux,
        2 => Policy::SpaceMuxMps { anomaly_seed: rng.next_u64() },
        3 => Policy::SpaceMuxStreams,
        _ => Policy::SpaceTime { max_batch: 1 + rng.gen_range(64) as u32 },
    }
}

#[test]
fn prop_every_policy_conserves_inferences() {
    run_prop("conservation", 0xA0, 96, |rng| {
        let n = 1 + rng.gen_range(12) as usize;
        let iters = 1 + rng.gen_range(10) as u32;
        let shape = rand_shape(rng);
        let policy = policies(rng);
        let cfg = SimConfig::new(DeviceSpec::v100(), policy);
        let report = gpusim::run(&cfg, &sgemm_tenants(n, iters, shape));
        assert_eq!(report.total_completed(), n as u64 * iters as u64);
        for t in &report.tenants {
            assert_eq!(t.completed, iters as u64, "every tenant finishes");
            assert_eq!(t.latencies.len(), iters as usize);
            assert!(t.latencies.iter().all(|&l| l > 0.0));
        }
        assert!(report.makespan > 0.0);
        assert!(report.makespan.is_finite());
    });
}

#[test]
fn prop_throughput_bounded_by_peak() {
    run_prop("roofline bound", 0xA1, 96, |rng| {
        let spec = DeviceSpec::v100();
        let peak = spec.peak_flops();
        let policy = policies(rng);
        let cfg = SimConfig::new(spec, policy);
        let n = 1 + rng.gen_range(24) as usize;
        let report = gpusim::run(&cfg, &sgemm_tenants(n, 5, rand_shape(rng)));
        assert!(
            report.throughput_flops() <= peak * 1.001,
            "{}: {:.3e} > peak {:.3e}",
            cfg.policy.label(),
            report.throughput_flops(),
            peak
        );
    });
}

#[test]
fn prop_kernel_time_monotone_in_work() {
    // More FLOPs (K depth) at fixed resources never gets faster.
    check("service time monotone in K", 0xA2, |rng| {
        let spec = DeviceSpec::v100();
        let ctx = CostCtx::exclusive(&spec);
        let m = 1 + sized(rng, 512) as u32;
        let n = 1 + sized(rng, 512) as u32;
        let k1 = 1 + sized(rng, 1024) as u32;
        let k2 = k1 + 1 + sized(rng, 1024) as u32;
        let t1 = kernel_service_time(&spec, &KernelDesc::sgemm(0, GemmShape::new(m, n, k1)), &ctx);
        let t2 = kernel_service_time(&spec, &KernelDesc::sgemm(0, GemmShape::new(m, n, k2)), &ctx);
        assert!(t2 >= t1, "K {k1}->{k2} made kernel faster: {t1} -> {t2}");
    });
}

#[test]
fn prop_superkernel_beats_sum_of_parts() {
    // One fused R-problem launch is never slower than R sequential
    // launches of the same problem under exclusive cost (launch overhead
    // amortization — the space-time mechanism).
    check("fusion amortizes overhead", 0xA3, |rng| {
        let spec = DeviceSpec::v100();
        let shape = rand_shape(rng);
        let r = 2 + rng.gen_range(63) as usize;
        let parts: Vec<KernelDesc> =
            (0..r).map(|t| KernelDesc::sgemm(t, shape)).collect();
        let fused = KernelDesc::superkernel(&parts);
        let t_fused = exclusive_time(&spec, &fused);
        let t_seq: f64 = parts.iter().map(|k| exclusive_time(&spec, k)).sum();
        assert!(
            t_fused <= t_seq * 1.0001,
            "fused {t_fused:.3e} slower than sequential {t_seq:.3e} (R={r})"
        );
    });
}

#[test]
fn prop_superkernel_conserves_flops() {
    check("superkernel flops additive", 0xA4, |rng| {
        let r = 1 + rng.gen_range(64) as usize;
        // Same shape across parts — the batcher invariant superkernel()
        // asserts (cross-shape fusion is the batcher's job to prevent).
        let shape = rand_shape(rng);
        let parts: Vec<KernelDesc> = (0..r)
            .map(|t| KernelDesc::sgemm(t, shape))
            .collect();
        let fused = KernelDesc::superkernel(&parts);
        let sum: f64 = parts.iter().map(|k| k.flops).sum();
        assert!(
            (fused.flops - sum).abs() <= sum * 1e-9,
            "fused flops {} != sum {}",
            fused.flops,
            sum
        );
    });
}

#[test]
fn prop_time_mux_latency_monotone_in_tenants() {
    // Adding tenants under time multiplexing never reduces mean latency.
    run_prop("time-mux monotone", 0xA5, 48, |rng| {
        let shape = rand_shape(rng);
        let n1 = 1 + rng.gen_range(8) as usize;
        let n2 = n1 + 1 + rng.gen_range(8) as usize;
        let lat = |n: usize| {
            let cfg = SimConfig::new(DeviceSpec::v100(), Policy::TimeMux);
            gpusim::run(&cfg, &sgemm_tenants(n, 5, shape)).mean_latency()
        };
        let l1 = lat(n1);
        let l2 = lat(n2);
        assert!(
            l2 >= l1 * 0.999,
            "{n1}->{n2} tenants reduced time-mux latency {l1:.3e}->{l2:.3e}"
        );
    });
}

#[test]
fn prop_exclusive_latency_independent_of_tenant_count() {
    // Exclusive = private device per tenant: per-inference latency must not
    // depend on how many other tenants exist.
    run_prop("exclusive isolation", 0xA6, 48, |rng| {
        let shape = rand_shape(rng);
        let lat = |n: usize| {
            let cfg = SimConfig::new(DeviceSpec::v100(), Policy::Exclusive);
            gpusim::run(&cfg, &sgemm_tenants(n, 5, shape)).mean_latency()
        };
        let l1 = lat(1);
        let l8 = lat(1 + rng.gen_range(16) as usize);
        let rel = (l8 - l1).abs() / l1;
        assert!(rel < 1e-9, "exclusive latency changed with tenants: {rel}");
    });
}

#[test]
fn prop_trace_events_cover_makespan_without_overlap_violations() {
    run_prop("trace well-formed", 0xA7, 48, |rng| {
        let policy = policies(rng);
        let cfg = SimConfig::new(DeviceSpec::v100(), policy).with_trace();
        let n = 1 + rng.gen_range(8) as usize;
        let report = gpusim::run(&cfg, &sgemm_tenants(n, 3, rand_shape(rng)));
        let trace = &report.trace;
        assert!(trace.launches() > 0);
        for ev in &trace.events {
            assert!(ev.t_start >= 0.0);
            assert!(ev.t_end > ev.t_start, "zero/negative-length event");
            assert!(ev.t_end <= report.makespan * (1.0 + 1e-9));
        }
    });
}

#[test]
fn prop_deterministic_given_seed() {
    // Same config + workload -> identical report (required for the benches
    // to be reproducible).
    run_prop("determinism", 0xA8, 32, |rng| {
        let seed = rng.next_u64();
        let shape = rand_shape(rng);
        let n = 1 + rng.gen_range(10) as usize;
        let run = || {
            let cfg = SimConfig::new(
                DeviceSpec::v100(),
                Policy::SpaceMuxMps { anomaly_seed: seed },
            );
            gpusim::run(&cfg, &sgemm_tenants(n, 4, shape))
        };
        let a = run();
        let b = run();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.kernel_launches, b.kernel_launches);
        assert_eq!(a.straggler_gap(), b.straggler_gap());
    });
}
