//! Integration: concurrent spatial lanes — interference-model calibration
//! against the gpusim ground truth, lane-balanced round replay, and the
//! coordinator-level `lanes` knob.
//!
//! Pure logic (no PJRT artifacts) except the final end-to-end test, which
//! skips without `artifacts/`.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::scheduler::SpaceTimeSched;
use stgpu::coordinator::{
    Coordinator, CostModel, InferenceRequest, Priority, QueueSet, Scheduler, ShapeClass,
};
use stgpu::gpusim::cost::{kernel_service_time, CostCtx};
use stgpu::gpusim::{DeviceSpec, GemmShape, KernelDesc};
use stgpu::util::prng::Rng;

const CLASSES: [ShapeClass; 4] = [
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1024 },
];

/// gpusim ground truth for a fused launch of `r` problems of `class`
/// executing while `active` spatial lanes share the device (static SM
/// split + deterministic interference derate) — the same physics the
/// lane-aware simulator and fig10 use.
fn ground_truth(spec: &DeviceSpec, class: ShapeClass, r: usize, active: usize) -> f64 {
    let shape =
        GemmShape::new(class.m.max(1) as u32, class.n.max(1) as u32, class.k.max(1) as u32);
    let mut merged = KernelDesc::sgemm(0, shape);
    let r = r.max(1);
    merged.flops *= r as f64;
    merged.bytes *= r as f64;
    merged.ctas = merged.ctas.saturating_mul(r as u32);
    merged.fused = r as u32;
    let active = active.max(1);
    spec.launch_overhead_s
        + kernel_service_time(
            spec,
            &merged,
            &CostCtx {
                sms: spec.sms as f64 / active as f64,
                concurrency: active as u32,
                static_bw_partition: false,
            },
        )
}

#[test]
fn interference_calibration_converges_and_error_stays_bounded() {
    // Close the calibration loop against the simulator ground truth: after
    // a handful of overlapped rounds per lane count, the learned stretch
    // matches the measured co-location slowdown and the exported per-lane
    // calibration error is tightly bounded.
    let spec = DeviceSpec::v100();
    let class = CLASSES[0];
    let mut cm = CostModel::new();
    for _ in 0..20 {
        cm.observe(class, 4, ground_truth(&spec, class, 4, 1));
    }
    for lanes in [2usize, 4] {
        for _ in 0..60 {
            cm.observe_concurrent(class, 4, lanes, ground_truth(&spec, class, 4, lanes));
        }
    }
    for lanes in [2usize, 4] {
        let err = cm.lane_calibration_error(lanes);
        assert!(err < 0.05, "lane count {lanes}: calibration error {err}");
    }
    let exported: Vec<usize> = cm.lane_calibration().iter().map(|&(l, _)| l).collect();
    assert_eq!(exported, vec![2, 4], "both observed lane counts export");
    // The learned stretches reflect the physics: sharing hurts, more
    // sharers hurt more, and the 2-lane stretch sits well above the
    // analytic 1.08 seed (occupancy effects dominate the linear term).
    assert!(cm.lane_stretch(2) > 1.0);
    assert!(cm.lane_stretch(4) > cm.lane_stretch(2));
    // Solo predictions stay clean: overlapped samples were deflated.
    let solo = cm.predict(class, 4);
    let truth = ground_truth(&spec, class, 4, 1);
    assert!(
        (solo - truth).abs() / truth < 0.05,
        "solo track polluted: {solo} vs {truth}"
    );
}

/// Replay a fixed multi-class backlog through the lane-aware scheduler on
/// a simulated clock with gpusim ground-truth durations; returns
/// (makespan, completed, observed lane counts fed to `cost`).
fn drain_backlog(lanes: usize, cost: &Arc<Mutex<CostModel>>) -> (f64, usize) {
    let spec = DeviceSpec::v100();
    let now = Instant::now();
    let mut q = QueueSet::new(8, 64);
    let mut id = 0u64;
    for _round in 0..4 {
        for (c, &class) in CLASSES.iter().enumerate() {
            for t in [2 * c, 2 * c + 1] {
                q.push(InferenceRequest {
                    id,
                    tenant: t,
                    class,
                    payload: vec![],
                    arrived: now,
                    deadline: now,
                    priority: Priority::Normal,
                    trace_id: 0,
                })
                .unwrap();
                id += 1;
            }
        }
    }
    let mut sched = SpaceTimeSched::new(vec![1, 2, 4, 8, 16, 32, 64], 16)
        .spatial_lanes(lanes, Some(cost.clone()));
    let mut clock = 0.0f64;
    let mut completed = 0usize;
    while !q.is_empty() {
        let plan = sched.plan_round(&mut q);
        let active = plan.lanes_used().max(1);
        let mut lane_time = vec![0.0f64; plan.n_lanes.max(1)];
        for (i, launch) in plan.launches.iter().enumerate() {
            let dur = ground_truth(&spec, launch.class, launch.r_bucket, active);
            lane_time[plan.lane(i)] += dur;
            cost.lock().unwrap().observe_concurrent(
                launch.class,
                launch.r_bucket,
                active,
                dur,
            );
            completed += launch.entries.len();
        }
        clock += lane_time.iter().cloned().fold(0.0, f64::max);
    }
    (clock, completed)
}

#[test]
fn two_lanes_strictly_beat_one_on_a_multi_class_backlog() {
    // Four shape classes of ~128-CTA super-kernels: serial rounds leave
    // the 80-SM device under-occupied per launch; two lanes overlap them
    // and drain the same backlog strictly faster — the tier-1 version of
    // the fig10 claim.
    let cost1 = Arc::new(Mutex::new(CostModel::new()));
    let (serial, done1) = drain_backlog(1, &cost1);
    let cost2 = Arc::new(Mutex::new(CostModel::new()));
    let (dual, done2) = drain_backlog(2, &cost2);
    assert_eq!(done1, done2, "both drain the whole backlog");
    assert!(
        dual < serial * 0.9,
        "2-lane makespan {dual} should be >10% below serial {serial}"
    );
    // The 2-lane run actually exercised the interference model and its
    // error stayed bounded.
    let cm = cost2.lock().unwrap();
    let calib = cm.lane_calibration();
    assert!(
        calib.iter().any(|&(l, _)| l == 2),
        "2-lane rounds must feed the interference model, got {calib:?}"
    );
    assert!(
        cm.lane_calibration_error(2) < 0.25,
        "interference calibration error {} unbounded",
        cm.lane_calibration_error(2)
    );
}

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn coordinator_runs_lane_rounds_end_to_end() {
    // End-to-end (needs artifacts): a lanes=2 coordinator serves two
    // distinct shape classes, executes overlapped lane rounds, and
    // accounts launches per lane in the device snapshot.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        lanes: 2,
        artifacts_dir: dir,
        tenants: vec![
            TenantConfig {
                name: "a".into(),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: 0,
            },
            TenantConfig {
                name: "b".into(),
                model: "sgemm:256x256x256".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: 1,
            },
        ],
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    assert_eq!(coord.lanes(), 2);
    let mut rng = Rng::new(11);
    for t in 0..2usize {
        for _ in 0..3 {
            let payload = coord.random_payload(t, &mut rng);
            coord.submit(t, payload).unwrap();
        }
    }
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 6);
    let snaps = coord.device_snapshots();
    let lane_total: u64 = snaps[0].lane_launches.iter().sum();
    assert_eq!(lane_total, snaps[0].launches, "per-lane accounting ties out");
    assert_eq!(snaps[0].lane_launches.len(), 2);
    assert!(snaps[0].lane_busy_s.iter().any(|&b| b > 0.0));
}

#[test]
fn steal_off_coordinator_reports_zero_lane_steals() {
    // `steal = false` (the default): per-lane queues stay strictly
    // private, so the stealing machinery must remain fully disengaged —
    // every lane counter reads zero and the plain lane accounting still
    // ties out exactly as it did before stealing existed.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        lanes: 2,
        steal: false,
        artifacts_dir: dir,
        tenants: vec![
            TenantConfig {
                name: "a".into(),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: 0,
            },
            TenantConfig {
                name: "b".into(),
                model: "sgemm:256x256x256".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: 1,
            },
        ],
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(31);
    for t in 0..2usize {
        for _ in 0..4 {
            let p = coord.random_payload(t, &mut rng);
            coord.submit(t, p).unwrap();
        }
    }
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 8);
    let snaps = coord.device_snapshots();
    assert!(
        snaps[0].lane_steals.iter().all(|&s| s == 0),
        "steal-off must never record a steal: {:?}",
        snaps[0].lane_steals
    );
    let lane_total: u64 = snaps[0].lane_launches.iter().sum();
    assert_eq!(lane_total, snaps[0].launches);
}

#[test]
fn stealing_coordinator_preserves_numerics_end_to_end() {
    // Work stealing moves launches between lanes; it must never change
    // WHAT is computed. Each work item carries its launch/spec/weights,
    // so the executing lane is irrelevant to the numerics: every request
    // completes exactly once and matches the host GEMM reference.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        lanes: 2,
        steal: true,
        steal_min_queue: 1,
        artifacts_dir: dir,
        tenants: (0..4)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                // Imbalanced classes: heavy K on even tenants makes one
                // lane's queue run long, giving thieves something to take.
                model: if i % 2 == 0 {
                    "sgemm:256x128x1152".into()
                } else {
                    "sgemm:256x256x256".into()
                },
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(32);
    let mut sent: Vec<(u64, Vec<stgpu::runtime::HostTensor>)> = Vec::new();
    for wave in 0..3 {
        for t in 0..4usize {
            for _ in 0..2 {
                let p = coord.random_payload(t, &mut rng);
                let id = coord.submit(t, p.clone()).unwrap();
                sent.push((id, p));
            }
        }
        let _ = wave;
        let responses = coord.run_until_drained().unwrap();
        for (id, payload) in sent.drain(..) {
            let resp = responses
                .iter()
                .find(|r| r.id == id)
                .unwrap_or_else(|| panic!("no response for request {id}"));
            let a = stgpu::runtime::HostTensor::stack(&[&payload[0]], 1);
            let b = stgpu::runtime::HostTensor::stack(&[&payload[1]], 1);
            let want = stgpu::runtime::host_batched_gemm(&a, &b).slice_problem(0);
            let diff = resp.output.max_abs_diff(&want);
            assert!(diff < 1e-2, "request {id}: diff {diff}");
        }
    }
    // Stealing is permitted but not required here (timing-dependent);
    // what IS required is that the accounting stays coherent.
    let snaps = coord.device_snapshots();
    let lane_total: u64 = snaps[0].lane_launches.iter().sum();
    assert_eq!(lane_total, snaps[0].launches);
}
