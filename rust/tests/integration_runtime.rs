//! Integration: the full AOT bridge — python-lowered HLO text artifacts
//! loaded, compiled, and executed through the PJRT CPU client, validated
//! against the rust host oracle.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use stgpu::runtime::{host_batched_gemm, host_fused_linear, HostTensor, PjrtEngine};
use stgpu::util::prng::Rng;

fn engine() -> Option<PjrtEngine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::new(dir).expect("engine"))
}

#[test]
fn manifest_lists_all_kinds_and_buckets() {
    let Some(eng) = engine() else { return };
    let m = eng.manifest();
    for kind in ["batched_gemm", "fused_linear", "mlp_block", "rnn_cell"] {
        for impl_ in ["pallas", "xla"] {
            let buckets = m.r_buckets(kind, impl_);
            assert_eq!(
                buckets,
                vec![1, 2, 4, 8, 16, 32, 64],
                "kind={kind} impl={impl_}"
            );
        }
    }
}

#[test]
fn xla_batched_gemm_matches_host_oracle() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(42);
    // square shape class, R bucket 2
    let a = HostTensor::random(&[2, 256, 256], &mut rng);
    let b = HostTensor::random(&[2, 256, 256], &mut rng);
    let out = eng.run("gemm_square_r2.xla", &[a.clone(), b.clone()]).unwrap();
    assert_eq!(out.len(), 1);
    let want = host_batched_gemm(&a, &b);
    let diff = out[0].max_abs_diff(&want);
    assert!(diff < 1e-2, "max abs diff {diff}");
}

#[test]
fn pallas_and_xla_flavors_agree_through_pjrt() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(7);
    let a = HostTensor::random(&[1, 512, 512], &mut rng);
    let b = HostTensor::random(&[1, 512, 1], &mut rng);
    let p = eng
        .run("gemm_rnn_matvec_r1.pallas", &[a.clone(), b.clone()])
        .unwrap();
    let x = eng.run("gemm_rnn_matvec_r1.xla", &[a, b]).unwrap();
    let diff = p[0].max_abs_diff(&x[0]);
    assert!(diff < 1e-3, "pallas vs xla diff {diff}");
}

#[test]
fn fused_linear_epilogue_matches_host() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(3);
    let a = HostTensor::random(&[2, 8, 512], &mut rng);
    let w = HostTensor::random(&[2, 512, 256], &mut rng);
    let bias = HostTensor::random(&[2, 1, 256], &mut rng);
    let out = eng
        .run("fused_linear_r2.xla", &[a.clone(), w.clone(), bias.clone()])
        .unwrap();
    let want = host_fused_linear(&a, &w, &bias);
    let diff = out[0].max_abs_diff(&want);
    assert!(diff < 1e-2, "diff {diff}");
    assert!(out[0].data.iter().all(|&v| v >= 0.0), "relu must clamp");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(5);
    let a = HostTensor::random(&[1, 256, 256], &mut rng);
    let b = HostTensor::random(&[1, 256, 256], &mut rng);
    let inputs = [a, b];
    eng.run("gemm_square_r1.xla", &inputs).unwrap();
    let s1 = eng.stats();
    for _ in 0..3 {
        eng.run("gemm_square_r1.xla", &inputs).unwrap();
    }
    let s2 = eng.stats();
    assert_eq!(s1.compiles, s2.compiles, "cache must prevent recompiles");
    assert_eq!(s2.executions, s1.executions + 3);
    assert!(s2.cache_hits >= 3);
}

#[test]
fn shape_mismatch_is_rejected_not_ub() {
    let Some(eng) = engine() else { return };
    let bad = HostTensor::zeros(&[3, 256, 256]); // wrong R for r1 artifact
    let ok = HostTensor::zeros(&[1, 256, 256]);
    assert!(eng.run("gemm_square_r1.xla", &[bad, ok]).is_err());
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(eng) = engine() else { return };
    assert!(eng.load("no_such_artifact").is_err());
}

#[test]
fn warmup_precompiles_matching_set() {
    let Some(eng) = engine() else { return };
    let n = eng
        .warmup(|a| a.kind == "mlp_block" && a.impl_ == "xla" && a.r() <= 2)
        .unwrap();
    assert_eq!(n, 2); // r1 + r2
    assert!(eng.cached_count() >= 2);
}

#[test]
fn mlp_block_runs_end_to_end() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(11);
    let x = HostTensor::random(&[1, 8, 256], &mut rng);
    let w1 = HostTensor::random(&[1, 256, 512], &mut rng);
    let b1 = HostTensor::random(&[1, 1, 512], &mut rng);
    let w2 = HostTensor::random(&[1, 512, 256], &mut rng);
    let out = eng
        .run("mlp_block_r1.xla", &[x.clone(), w1.clone(), b1.clone(), w2.clone()])
        .unwrap();
    assert_eq!(out[0].shape, vec![1, 8, 256]);
    // Oracle: relu(x@w1+b1) @ w2 on the host.
    let h = host_fused_linear(&x, &w1, &b1);
    let want = host_batched_gemm(&h, &w2);
    assert!(out[0].max_abs_diff(&want) < 1e-2);
}

#[test]
fn superkernel_problems_are_isolated() {
    // Isolation (paper §4): problem r's output must not depend on what else
    // is in the super-kernel batch — including zero padding.
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(13);
    let a0 = HostTensor::random(&[256, 256], &mut rng);
    let b0 = HostTensor::random(&[256, 256], &mut rng);
    // Run solo in the r1 executable...
    let solo = eng
        .run(
            "gemm_square_r1.xla",
            &[
                HostTensor::stack(&[&a0], 1),
                HostTensor::stack(&[&b0], 1),
            ],
        )
        .unwrap();
    // ...and padded into the r4 executable alongside zeros.
    let padded = eng
        .run(
            "gemm_square_r4.xla",
            &[
                HostTensor::stack(&[&a0], 4),
                HostTensor::stack(&[&b0], 4),
            ],
        )
        .unwrap();
    let diff = solo[0].slice_problem(0).max_abs_diff(&padded[0].slice_problem(0));
    assert!(diff < 1e-4, "batch padding changed problem 0: {diff}");
    // Padding lanes are exactly zero.
    for r in 1..4 {
        assert!(padded[0].slice_problem(r).data.iter().all(|&v| v == 0.0));
    }
}
