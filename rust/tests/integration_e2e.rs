//! End-to-end: config file → coordinator → threaded server → concurrent
//! closed-loop clients → metrics, across all four schedulers — the full
//! stack the `stgpu serve` binary runs, validated in-process.
//!
//! Requires `make artifacts` (skips otherwise).

use std::time::{Duration, Instant};

use stgpu::config::ServerConfig;
use stgpu::coordinator::Coordinator;
use stgpu::server::{ServeOpts, Server};
use stgpu::util::prng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built");
        None
    }
}

/// The e2e config is written as TOML and round-tripped through the real
/// config loader — the same path `stgpu serve --config` takes.
fn load_config(scheduler: &str, dir: &std::path::Path) -> ServerConfig {
    let toml = format!(
        r#"
        [server]
        scheduler = "{scheduler}"
        max_batch = 32
        batch_timeout_us = 300
        queue_depth = 64
        artifacts_dir = "{}"

        [[tenant]]
        name = "mlp-a"
        model = "mlp"
        slo_ms = 250.0
        weight_seed = 1

        [[tenant]]
        name = "mlp-b"
        model = "mlp"
        slo_ms = 250.0
        weight_seed = 2

        [[tenant]]
        name = "mlp-c"
        model = "mlp"
        slo_ms = 250.0
        weight_seed = 3

        [[tenant]]
        name = "mlp-d"
        model = "mlp"
        slo_ms = 250.0
        weight_seed = 4
        "#,
        dir.display()
    );
    let doc = stgpu::config::TomlDoc::parse(&toml).expect("toml");
    ServerConfig::from_doc(&doc).expect("config")
}

/// Run a closed-loop workload: one client thread per tenant, each keeping
/// `DEPTH` requests outstanding (the saturated-queue setting of paper §2 —
/// "request queues are always saturated"). Returns (completed, snapshot).
fn run_workload(
    cfg: &ServerConfig,
    duration: Duration,
) -> (u64, stgpu::metrics::Snapshot) {
    const DEPTH: usize = 8;
    let coord = Coordinator::new(cfg).unwrap();
    coord.warmup().unwrap();
    let server = Server::start(
        coord,
        ServeOpts { batch_timeout: Duration::from_micros(cfg.batch_timeout_us), ..Default::default() },
    );
    let stop_at = Instant::now() + duration;
    let mut clients = Vec::new();
    for t in 0..cfg.tenants.len() {
        let h = server.handle();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(7000 + t as u64);
            let mut ok = 0u64;
            while Instant::now() < stop_at {
                // Keep DEPTH in flight, then reap the whole window.
                let pending: Vec<_> = (0..DEPTH)
                    .map(|_| {
                        let payload =
                            vec![stgpu::runtime::HostTensor::random(&[8, 256], &mut rng)];
                        h.submit(t, payload)
                    })
                    .collect();
                for rx in pending {
                    if matches!(rx.recv(), Ok(Ok(_))) {
                        ok += 1;
                    }
                }
            }
            ok
        }));
    }
    let completed: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let coord = server.shutdown();
    (completed, coord.snapshot())
}

#[test]
fn e2e_space_time_serves_and_fuses() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = load_config("space-time", &dir);
    let (completed, snap) = run_workload(&cfg, Duration::from_millis(1500));
    assert!(completed > 20, "completed only {completed}");
    assert_eq!(snap.total_completed(), completed);
    assert!(
        snap.superkernel_launches > 0,
        "space-time must fuse cross-tenant work"
    );
    // Every tenant made progress (fairness).
    for (name, t) in &snap.tenants {
        assert!(t.completed > 0, "tenant {name} starved");
    }
}

#[test]
fn e2e_all_schedulers_complete_same_workload() {
    let Some(dir) = artifacts_dir() else { return };
    let mut results = Vec::new();
    for sched in ["exclusive", "time-mux", "space-mux", "space-time"] {
        let cfg = load_config(sched, &dir);
        let (completed, snap) = run_workload(&cfg, Duration::from_millis(800));
        assert!(completed > 0, "{sched} served nothing");
        assert_eq!(snap.total_completed(), completed, "{sched} lost requests");
        results.push((sched, completed, snap));
    }
    // The space-time run must not be the worst performer: on the real CPU
    // path its advantage is launch amortization, so it should complete at
    // least as much as time-mux.
    let get = |name: &str| results.iter().find(|(s, ..)| *s == name).unwrap().1;
    let st = get("space-time");
    let tm = get("time-mux");
    assert!(
        st as f64 >= tm as f64 * 0.8,
        "space-time {st} fell behind time-mux {tm}"
    );
}

#[test]
fn e2e_latency_predictability_across_tenants() {
    // Paper criterion: predictability — same-architecture tenants under
    // space-time should see comparable p50s (no straggler tenant).
    let Some(dir) = artifacts_dir() else { return };
    let cfg = load_config("space-time", &dir);
    let (_, snap) = run_workload(&cfg, Duration::from_millis(1500));
    let p50s: Vec<f64> = snap
        .tenants
        .values()
        .filter(|t| t.completed >= 5)
        .map(|t| t.latency_p50_ns as f64)
        .collect();
    assert!(p50s.len() >= 3, "not enough sampled tenants");
    let fast = p50s.iter().cloned().fold(f64::INFINITY, f64::min);
    let slow = p50s.iter().cloned().fold(0.0, f64::max);
    assert!(
        slow / fast < 3.0,
        "tenant p50 spread too wide: {:.2}x (fast {fast:.0} ns, slow {slow:.0} ns)",
        slow / fast
    );
}

#[test]
fn e2e_metrics_account_every_request() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = load_config("space-time", &dir);
    let coord = Coordinator::new(&cfg).unwrap();
    let server = Server::start(coord, ServeOpts::default());
    let h = server.handle();
    let mut rng = Rng::new(11);
    let mut ok = 0u64;
    for i in 0..20 {
        let t = i % 4;
        let payload = vec![stgpu::runtime::HostTensor::random(&[8, 256], &mut rng)];
        if h.submit_blocking(t, payload).is_ok() {
            ok += 1;
        }
    }
    let coord = server.shutdown();
    let snap = coord.snapshot();
    assert_eq!(snap.total_completed(), ok);
    let per_tenant: u64 = snap.tenants.values().map(|t| t.completed).sum();
    assert_eq!(per_tenant, ok, "per-tenant counts must sum to total");
    assert!(snap.cache_misses <= 7 * 4, "bounded by warmup set");
}
