//! Schedule-exhaustive model checking of the **work-stealing** deque
//! protocol.
//!
//! Companion to `tests/modelcheck_protocol.rs` (which pins the base
//! dispatch/collect/resize/shutdown protocol): these tests turn
//! [`LaneProtocol::set_steal`] ON and explore every interleaving of owner
//! pops, back-of-queue steals, and collection under [`ModelEnv`]. The
//! invariants are the ones the production driver relies on:
//!
//! * **Conservation with stealing on** — every dispatched item surfaces
//!   exactly once, whether the owner ran it or a thief did.
//! * **Attribution** — the *planned* lane tag survives a steal untouched
//!   (cost-model feedback attributes to the plan), while the executed
//!   lane and stolen flag report where it actually ran.
//! * **Privacy with stealing off** — `steal = false` is bit-for-bit the
//!   pre-steal SPSC pool: only the owner ever executes a lane's items.
//!
//! The `mutation_*` tests re-introduce the two canonical stealing bugs and
//! prove the checker CATCHES them — the tooling's own regression suite:
//! * **steal-by-copy** (thief reads the victim's back without popping):
//!   the item executes twice and the duplicate completion is reported;
//! * **lost steal** (thief pops the victim's back, then drops the item
//!   instead of running it): the driver waits on a completion that can
//!   never arrive and the checker reports the deadlock.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, PoisonError};

use stgpu::coordinator::protocol::{
    ItemRunner, LaneProtocol, LaneTagged, ProtoPayload, ProtoReceiver, ProtoSender, SyncEnv,
};
use stgpu::util::modelcheck::{explore, CheckOpts, ModelEnv};

// ---------------------------------------------------------------------------
// Model payloads: items that remember where they actually executed.
// ---------------------------------------------------------------------------

struct SItem {
    id: u64,
    lane: usize,
    executed: usize,
    stolen: bool,
}

impl SItem {
    fn new(id: u64, lane: usize) -> Self {
        Self { id, lane, executed: usize::MAX, stolen: false }
    }
}

impl ProtoPayload for SItem {
    fn fingerprint(&self) -> u64 {
        self.id ^ ((self.lane as u64) << 8)
    }
}

impl LaneTagged for SItem {
    fn lane(&self) -> usize {
        self.lane
    }
    fn set_lane(&mut self, lane: usize) {
        self.lane = lane;
    }
    fn set_executed(&mut self, lane: usize, stolen: bool) {
        self.executed = lane;
        self.stolen = stolen;
    }
}

struct SDone {
    id: u64,
    planned: usize,
    executed: usize,
    stolen: bool,
}

impl ProtoPayload for SDone {
    fn fingerprint(&self) -> u64 {
        self.id ^ ((self.executed as u64) << 8) ^ ((self.stolen as u64) << 16)
    }
}

/// Yields once mid-execution so the explorer can park a worker between
/// taking an item (under the deque lock) and reporting it — the window
/// where a racing thief must NOT be able to double-take the item.
struct SRunner;

impl ItemRunner<SItem, SDone> for SRunner {
    fn run(&self, item: SItem) -> SDone {
        ModelEnv::yield_now();
        SDone {
            id: item.id,
            planned: item.lane,
            executed: item.executed,
            stolen: item.stolen,
        }
    }
}

fn model_pool(lanes: usize) -> LaneProtocol<ModelEnv, SItem, SDone> {
    LaneProtocol::new(lanes, Arc::new(SRunner))
}

/// Mark `id` collected exactly once in `seen`.
fn mark(seen: &mut [bool], id: u64) {
    let slot = &mut seen[id as usize];
    assert!(!*slot, "completion {id} surfaced twice");
    *slot = true;
}

// ---------------------------------------------------------------------------
// Trunk protocol checks (must pass on every schedule)
// ---------------------------------------------------------------------------

#[test]
fn model_stealing_conserves_items_and_attributes_both_lanes() {
    // Three threads (driver + 2 workers), all work planned onto lane 0,
    // stealing on: on every schedule each item runs exactly once — owner
    // or thief — the planned tag survives, and the steal counter agrees
    // with the completions' stolen flags.
    let opts = CheckOpts { max_preemptions: 1, ..CheckOpts::default() };
    let stats = explore("steal-conserve", opts, || {
        let mut pool = model_pool(2);
        pool.set_steal(true);
        for id in 0..3 {
            pool.dispatch(SItem::new(id, 0));
        }
        let mut seen = [false; 3];
        let mut stolen_seen = 0u64;
        for _ in 0..3 {
            let d = pool.collect().expect("workers alive");
            mark(&mut seen, d.id);
            assert_eq!(d.planned, 0, "planned lane tag must survive stealing");
            if d.stolen {
                stolen_seen += 1;
                assert_eq!(d.executed, 1, "only lane 1 can steal lane 0's work");
            } else {
                assert_eq!(d.executed, 0, "un-stolen work runs on its owner");
            }
        }
        assert!(seen.iter().all(|&s| s), "an item was lost");
        assert_eq!(
            pool.steals_total(),
            stolen_seen,
            "steal counter must agree with completion attribution"
        );
        assert_eq!(pool.in_flight(), 0);
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("steal conservation: {stats}");
    assert!(!stats.truncated, "exploration must complete within bound");
    assert!(stats.schedules > 1);
}

#[test]
fn model_steal_off_keeps_lanes_private_on_every_schedule() {
    // The bit-identical claim at the protocol level: with stealing off
    // (the default), no schedule exists where an item executes anywhere
    // but its planned lane.
    let opts = CheckOpts { max_preemptions: 1, ..CheckOpts::default() };
    let stats = explore("steal-off-private", opts, || {
        let mut pool = model_pool(2);
        pool.dispatch(SItem::new(0, 0));
        pool.dispatch(SItem::new(1, 0));
        pool.dispatch(SItem::new(2, 1));
        let mut seen = [false; 3];
        for _ in 0..3 {
            let d = pool.collect().expect("workers alive");
            mark(&mut seen, d.id);
            assert_eq!(d.executed, d.planned, "steal off: owner executes");
            assert!(!d.stolen);
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(pool.steals_total(), 0, "no steals may be recorded");
        assert_eq!(pool.in_flight(), 0);
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("steal-off privacy: {stats}");
    assert!(!stats.truncated);
}

#[test]
fn model_resize_with_steal_on_drains_without_loss() {
    // Shrink 2 -> 1 with stealing enabled while the retired lane still
    // owes queued work: the drain re-homes the backlog and no schedule
    // loses or duplicates an item.
    let opts = CheckOpts { max_preemptions: 1, ..CheckOpts::default() };
    let stats = explore("steal-resize", opts, || {
        let mut pool = model_pool(2);
        pool.set_steal(true);
        pool.dispatch(SItem::new(0, 1));
        pool.dispatch(SItem::new(1, 1));
        pool.resize(1);
        pool.dispatch(SItem::new(2, 1)); // clamps onto lane 0
        let mut seen = [false; 3];
        for _ in 0..3 {
            let d = pool.collect().expect("workers alive");
            mark(&mut seen, d.id);
        }
        assert!(seen.iter().all(|&s| s), "resize lost stealable work");
        assert_eq!(pool.lanes(), 1);
        assert_eq!(pool.in_flight(), 0);
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("steal resize/drain: {stats}");
    assert!(!stats.truncated);
}

// ---------------------------------------------------------------------------
// Mutation checks: known-bad stealing variants the checker must catch
// ---------------------------------------------------------------------------

struct RawDone {
    id: u64,
}

impl ProtoPayload for RawDone {
    fn fingerprint(&self) -> u64 {
        self.id
    }
}

#[test]
fn mutation_steal_by_copy_double_executes_and_is_caught() {
    // Re-introduce the classic stealing bug: the thief READS the victim's
    // back entry without popping it (steal-by-copy). On schedules where
    // the owner has not yet drained that entry, it executes twice and the
    // duplicate completion surfaces — the checker must find such a
    // schedule. (Trunk pops under the same lock that owners pop under:
    // see `model_stealing_conserves_items_and_attributes_both_lanes`.)
    let err = explore("steal-by-copy", CheckOpts::default(), || {
        let q = Arc::new(Mutex::new(VecDeque::from([1u64, 2])));
        let (done_tx, done_rx) = ModelEnv::channel::<RawDone>();
        let (q2, tx2) = (q.clone(), done_tx.clone());
        let owner = ModelEnv::spawn("owner".into(), move || loop {
            let front = q2.lock().unwrap_or_else(PoisonError::into_inner).pop_front();
            match front {
                Some(id) => {
                    ModelEnv::yield_now(); // "execute"
                    let _ = tx2.send(RawDone { id });
                }
                None => return,
            }
        });
        let (q3, tx3) = (q, done_tx);
        let thief = ModelEnv::spawn("thief".into(), move || {
            // BUG: copy the back entry, leaving it for the owner too.
            let back =
                q3.lock().unwrap_or_else(PoisonError::into_inner).back().copied();
            if let Some(id) = back {
                ModelEnv::yield_now(); // "execute"
                let _ = tx3.send(RawDone { id });
            }
        });
        owner.join();
        thief.join();
        let mut seen = [false; 3];
        while let Some(d) = done_rx.try_recv() {
            mark(&mut seen, d.id); // panics on the double execution
        }
    })
    .expect_err("the checker must catch the double execution");
    assert!(err.message.contains("surfaced twice"), "got: {}", err.message);
    println!("steal-by-copy caught after {} schedule(s)", err.schedules);
}

#[test]
fn mutation_lost_steal_is_caught_as_a_stuck_collector() {
    // The other canonical bug: the thief POPS the victim's back entry,
    // then drops it on the floor instead of executing it. The driver then
    // waits for a completion that can never arrive; the checker must
    // report the stuck collector. (Trunk hands every popped item to the
    // runner before anything else can touch the deques.)
    let err = explore("lost-steal", CheckOpts::default(), || {
        let q = Arc::new(Mutex::new(VecDeque::from([1u64, 2])));
        let (done_tx, done_rx) = ModelEnv::channel::<RawDone>();
        let done_keep = done_tx.clone(); // driver keeps the channel open (as the pool does)
        let (q2, tx2) = (q.clone(), done_tx);
        let owner = ModelEnv::spawn("owner".into(), move || loop {
            let front = q2.lock().unwrap_or_else(PoisonError::into_inner).pop_front();
            match front {
                Some(id) => {
                    ModelEnv::yield_now();
                    let _ = tx2.send(RawDone { id });
                }
                None => return,
            }
        });
        let thief = ModelEnv::spawn("thief".into(), move || {
            // BUG: take the item and never run or report it.
            let _lost = q.lock().unwrap_or_else(PoisonError::into_inner).pop_back();
        });
        let mut seen = [false; 3];
        for _ in 0..2 {
            let d = done_rx.recv().expect("completion"); // never arrives when the steal is lost
            mark(&mut seen, d.id);
        }
        owner.join();
        thief.join();
        drop(done_keep);
    })
    .expect_err("the checker must catch the lost steal");
    assert!(err.message.contains("deadlock"), "got: {}", err.message);
    println!("lost steal caught after {} schedule(s)", err.schedules);
}
