//! Integration: the threaded serving frontend — concurrent clients,
//! batching window, snapshot, status endpoint, clean shutdown.
//!
//! Requires `make artifacts` (skips otherwise).

use std::io::Read;
use std::time::Duration;

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::Coordinator;
use stgpu::server::{ServeOpts, Server, StatusEndpoint};
use stgpu::util::prng::Rng;

fn config(scheduler: SchedulerKind, n_tenants: usize) -> Option<ServerConfig> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built");
        return None;
    }
    Some(ServerConfig {
        scheduler,
        artifacts_dir: dir,
        tenants: (0..n_tenants)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: "sgemm:64x32x48".into(),
                batch: 1,
                slo_ms: 1000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    })
}

fn start(cfg: &ServerConfig) -> Server {
    let coord = Coordinator::new(cfg).unwrap();
    coord.warmup().unwrap();
    Server::start(coord, ServeOpts::default())
}

#[test]
fn blocking_submit_roundtrips() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 2) else { return };
    let server = start(&cfg);
    let h = server.handle();
    let mut rng = Rng::new(1);
    let payload = vec![
        stgpu::runtime::HostTensor::random(&[64, 48], &mut rng),
        stgpu::runtime::HostTensor::random(&[48, 32], &mut rng),
    ];
    let resp = h.submit_blocking(0, payload).expect("response");
    assert_eq!(resp.tenant, 0);
    assert_eq!(resp.output.shape, vec![64, 32]);
    assert!(resp.latency_s > 0.0);
    let coord = server.shutdown();
    assert_eq!(coord.snapshot().total_completed(), 1);
}

#[test]
fn concurrent_clients_all_complete() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 4) else { return };
    let server = start(&cfg);
    let mut clients = Vec::new();
    for t in 0..4usize {
        let h = server.handle();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t as u64);
            let mut ok = 0;
            for _ in 0..10 {
                let payload = vec![
                    stgpu::runtime::HostTensor::random(&[64, 48], &mut rng),
                    stgpu::runtime::HostTensor::random(&[48, 32], &mut rng),
                ];
                if h.submit_blocking(t, payload).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert_eq!(total, 40);
    let coord = server.shutdown();
    let snap = coord.snapshot();
    assert_eq!(snap.total_completed(), 40);
    // Closed-loop with 4 concurrent clients: the batching window must have
    // fused at least some cross-tenant launches.
    assert!(
        snap.superkernel_launches > 0,
        "expected some fused launches, got 0 (kernel_launches={})",
        snap.kernel_launches
    );
}

#[test]
fn snapshot_while_serving() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 1) else { return };
    let server = start(&cfg);
    let h = server.handle();
    let snap = h.snapshot().expect("snapshot");
    assert_eq!(snap.total_completed(), 0);
    let mut rng = Rng::new(2);
    let payload = vec![
        stgpu::runtime::HostTensor::random(&[64, 48], &mut rng),
        stgpu::runtime::HostTensor::random(&[48, 32], &mut rng),
    ];
    h.submit_blocking(0, payload).unwrap();
    let snap = h.snapshot().expect("snapshot");
    assert_eq!(snap.total_completed(), 1);
    server.shutdown();
}

#[test]
fn bad_tenant_rejected_without_hanging() {
    let Some(cfg) = config(SchedulerKind::TimeMux, 1) else { return };
    let server = start(&cfg);
    let h = server.handle();
    let res = h.submit_blocking(7, vec![]);
    assert!(res.is_err());
    server.shutdown();
}

#[test]
fn status_endpoint_serves_json() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 1) else { return };
    let server = start(&cfg);
    let ep = StatusEndpoint::start("127.0.0.1:0", server.handle()).unwrap();
    let addr = ep.addr();
    let mut body = String::new();
    {
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        sock.read_to_string(&mut body).unwrap();
    }
    assert!(body.contains("\"tenants\""), "status body: {body}");
    let parsed = stgpu::util::json::Json::parse(body.trim()).expect("valid json");
    assert!(parsed.get("wall_seconds").is_some());
    ep.stop();
    server.shutdown();
}

#[test]
fn shutdown_drains_inflight() {
    let Some(cfg) = config(SchedulerKind::TimeMux, 2) else { return };
    let server = start(&cfg);
    let h = server.handle();
    let mut rng = Rng::new(3);
    // Fire-and-collect: submit a burst, then shut down; every receiver must
    // resolve (either a response or a shutdown rejection) — no hangs.
    let mut pending = Vec::new();
    for t in 0..2usize {
        for _ in 0..5 {
            let payload = vec![
                stgpu::runtime::HostTensor::random(&[64, 48], &mut rng),
                stgpu::runtime::HostTensor::random(&[48, 32], &mut rng),
            ];
            pending.push(h.submit(t, payload));
        }
    }
    std::thread::sleep(Duration::from_millis(50));
    let coord = server.shutdown();
    let mut resolved = 0;
    for rx in pending {
        if rx.recv_timeout(Duration::from_secs(5)).is_ok() {
            resolved += 1;
        }
    }
    assert_eq!(resolved, 10, "every submission resolves");
    assert!(coord.snapshot().total_completed() <= 10);
}
