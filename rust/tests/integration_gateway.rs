//! Integration: the gateway tier — layered admission over a scriptable
//! backend shard, on a virtual clock (no artifacts needed).
//!
//! Covers the four behaviours the PR's acceptance gates on:
//!   1. auth rejection happens before any other layer does work (no
//!      token spent, backend never called);
//!   2. token-bucket refill timing, including the isolation-class rate
//!      multipliers and the exact `retry_after` hint;
//!   3. the breaker trip → shed → half-open → close cycle against an
//!      injected always-overloaded shard, with call-count proof that an
//!      open breaker stops backend traffic at the gateway;
//!   4. end-to-end deadline propagation: the contexts the gateway builds
//!      from wire fields resolve to wire deadlines, and the EDF heap
//!      pops in wire-deadline order — the config SLO applies only to
//!      requests that named no deadline.
//! Plus the reactor's TCP wire protocol over the same stack.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use stgpu::config::{GatewayConfig, GatewayTenant, IsolationClass};
use stgpu::coordinator::{
    DeadlineSpec, InferenceResponse, Priority, QueueSet, Reject, RequestContext, ShapeClass,
};
use stgpu::runtime::HostTensor;
use stgpu::server::gateway::reactor::gateway_handler;
use stgpu::server::{BackendReply, BreakerState, Gateway, GatewayBackend, Reactor, WireRequest};
use stgpu::util::json::Json;

/// One scriptable synchronous shard: records every admitted context and
/// replies with a fixed verdict (`None` = success).
struct FakeShard {
    verdict: Option<Reject>,
    calls: u64,
    ctxs: Vec<RequestContext>,
}

impl FakeShard {
    fn ok() -> Self {
        Self { verdict: None, calls: 0, ctxs: Vec::new() }
    }

    fn overloaded() -> Self {
        Self { verdict: Some(Reject::Overloaded), calls: 0, ctxs: Vec::new() }
    }
}

impl GatewayBackend for FakeShard {
    fn devices(&self) -> usize {
        1
    }

    fn device_of(&self, _tenant: usize) -> usize {
        0
    }

    fn submit(&mut self, ctx: RequestContext, _payload: Vec<HostTensor>) -> BackendReply {
        self.calls += 1;
        self.ctxs.push(ctx);
        match &self.verdict {
            Some(rej) => BackendReply::Ready(Err(rej.clone())),
            None => BackendReply::Ready(Ok(InferenceResponse {
                id: self.calls,
                tenant: ctx.tenant,
                output: HostTensor { shape: vec![1], data: vec![0.0] },
                latency_s: 0.001,
                service_s: 0.001,
                fused_r: 1,
                trace_id: ctx.trace_id,
            })),
        }
    }
}

fn cfg(keys: Vec<(&str, usize, IsolationClass)>, rate: f64, burst: f64) -> GatewayConfig {
    GatewayConfig {
        rate,
        burst,
        breaker_window: 4,
        breaker_threshold: 0.5,
        breaker_cooldown_ms: 100.0,
        half_open_probes: 2,
        tenants: keys
            .into_iter()
            .map(|(k, t, c)| GatewayTenant { api_key: k.into(), tenant: t, class: c })
            .collect(),
        ..GatewayConfig::default()
    }
}

fn wire(key: &str) -> WireRequest<'_> {
    WireRequest { api_key: key, budget_ms: None, priority: None, trace_id: 0 }
}

#[test]
fn auth_rejects_before_any_token_is_spent() {
    let t0 = Instant::now();
    let mut g = Gateway::new(
        &cfg(vec![("good", 0, IsolationClass::Standard)], 10.0, 2.0),
        FakeShard::ok(),
    );
    // Repeated unknown-key attempts: counted, but the backend is never
    // asked and no layer below auth runs.
    for _ in 0..3 {
        assert_eq!(g.admit(&wire("bad"), vec![], t0).unwrap_err(), Reject::AuthFailed);
    }
    assert_eq!(g.auth_failures(), 3);
    assert_eq!(g.backend().calls, 0);
    assert_eq!(g.stats().admitted, 0);
    // The valid tenant's FULL burst (2 tokens) is still there — the auth
    // failures spent none of it.
    assert!(g.admit(&wire("good"), vec![], t0).is_ok());
    assert!(g.admit(&wire("good"), vec![], t0).is_ok());
    assert!(matches!(
        g.admit(&wire("good"), vec![], t0),
        Err(Reject::RateLimited { .. })
    ));
}

#[test]
fn token_bucket_refills_on_schedule_with_class_multipliers() {
    let t0 = Instant::now();
    // Standard: 10 req/s, burst 2. Premium: x4 rate (40 req/s), x4 burst (8).
    let mut g = Gateway::new(
        &cfg(
            vec![
                ("std", 0, IsolationClass::Standard),
                ("pro", 1, IsolationClass::Premium),
            ],
            10.0,
            2.0,
        ),
        FakeShard::ok(),
    );
    // Standard: the burst passes, then the bucket names its exact refill.
    assert!(g.admit(&wire("std"), vec![], t0).is_ok());
    assert!(g.admit(&wire("std"), vec![], t0).is_ok());
    match g.admit(&wire("std"), vec![], t0) {
        Err(Reject::RateLimited { retry_after }) => {
            assert!((retry_after.as_secs_f64() - 0.1).abs() < 1e-6, "{retry_after:?}");
        }
        other => panic!("expected RateLimited, got {:?}", other.map(|_| ())),
    }
    // 99 ms later only 0.99 tokens have refilled: still limited.
    assert!(g.admit(&wire("std"), vec![], t0 + Duration::from_millis(99)).is_err());
    // 150 ms after the drain a whole token is back.
    assert!(g.admit(&wire("std"), vec![], t0 + Duration::from_millis(150)).is_ok());
    // ... and it was exactly one token.
    assert!(g.admit(&wire("std"), vec![], t0 + Duration::from_millis(150)).is_err());

    // Premium drains 8 burst tokens and refills 4x faster: 25 ms/token.
    for _ in 0..8 {
        assert!(g.admit(&wire("pro"), vec![], t0).is_ok());
    }
    match g.admit(&wire("pro"), vec![], t0) {
        Err(Reject::RateLimited { retry_after }) => {
            assert!((retry_after.as_secs_f64() - 0.025).abs() < 1e-6, "{retry_after:?}");
        }
        other => panic!("expected RateLimited, got {:?}", other.map(|_| ())),
    }
    assert!(g.admit(&wire("pro"), vec![], t0 + Duration::from_millis(30)).is_ok());
    assert_eq!(g.stats().rate_limited, 4);
    assert_eq!(g.stats().admitted, 12);
}

#[test]
fn breaker_cycle_against_an_overloaded_shard() {
    let t0 = Instant::now();
    // Big bucket so only the breaker is in play; window 4, threshold 0.5,
    // 100 ms cooldown, 2 clean probes to close.
    let mut g = Gateway::new(
        &cfg(vec![("k", 0, IsolationClass::Standard)], 1000.0, 1000.0),
        FakeShard::overloaded(),
    );
    // Four sustained overload verdicts fill the window: trip.
    for _ in 0..4 {
        assert_eq!(g.admit(&wire("k"), vec![], t0).unwrap_err(), Reject::Overloaded);
    }
    assert_eq!(g.breaker_state(0), BreakerState::Open);
    assert_eq!(g.backend().calls, 4);
    // Open: the gateway sheds and the shard is NOT called — provenance
    // names the shard and flags the breaker.
    let rej = g.admit(&wire("k"), vec![], t0 + Duration::from_millis(50)).unwrap_err();
    match &rej {
        Reject::BreakerOpen { device: 0, retry_after } => {
            assert!((retry_after.as_secs_f64() - 0.05).abs() < 1e-6, "{retry_after:?}");
        }
        other => panic!("expected BreakerOpen, got {other:?}"),
    }
    let prov = rej.provenance().expect("breaker sheds carry provenance");
    assert!(prov.breaker);
    assert_eq!(g.backend().calls, 4, "open breaker stops backend traffic");
    assert_eq!(g.stats().breaker_shed, 1);
    // Cooldown over, shard still drowning: the half-open probe fails and
    // the breaker re-opens for a full cooldown.
    let t1 = t0 + Duration::from_millis(100);
    assert_eq!(g.admit(&wire("k"), vec![], t1).unwrap_err(), Reject::Overloaded);
    assert_eq!(g.breaker_state(0), BreakerState::Open);
    assert_eq!(g.backend().calls, 5, "exactly one probe reached the shard");
    assert!(matches!(
        g.admit(&wire("k"), vec![], t1 + Duration::from_millis(99)).unwrap_err(),
        Reject::BreakerOpen { .. }
    ));
    // The shard recovers; two clean probes close the breaker.
    g.backend_mut().verdict = None;
    let t2 = t1 + Duration::from_millis(100);
    let ticket = g.admit(&wire("k"), vec![], t2).expect("probe 1 admitted");
    assert!(g.wait(ticket, t2).is_ok());
    assert_eq!(g.breaker_state(0), BreakerState::HalfOpen);
    let ticket = g.admit(&wire("k"), vec![], t2).expect("probe 2 admitted");
    assert!(g.wait(ticket, t2).is_ok());
    assert_eq!(g.breaker_state(0), BreakerState::Closed);
    assert_eq!(g.backend().calls, 7);
    // The status JSON reports the lifetime trip count (t0 and t1).
    let j = g.status_json(t2);
    let breakers = j.get("breakers").and_then(Json::as_arr).unwrap();
    assert_eq!(breakers[0].get("trips").and_then(Json::as_f64), Some(2.0));
    assert_eq!(breakers[0].get("state").and_then(Json::as_str), Some("closed"));
}

#[test]
fn wire_deadlines_order_the_edf_heap_not_the_config_slo() {
    let t0 = Instant::now();
    let mut g = Gateway::new(
        &cfg(vec![("k", 0, IsolationClass::Premium)], 1000.0, 1000.0),
        FakeShard::ok(),
    );
    // Four wire requests, submitted loosest-deadline first.
    let admit = |g: &mut Gateway<FakeShard>, budget_ms, priority, trace_id| {
        let w = WireRequest { api_key: "k", budget_ms, priority, trace_id };
        g.admit(&w, vec![], t0).expect("admitted");
    };
    admit(&mut g, Some(300.0), None, 1);
    admit(&mut g, Some(10.0), None, 2);
    admit(&mut g, None, None, 3); // no wire deadline: SLO default applies
    admit(&mut g, Some(10.0), Some(Priority::Batch), 4);

    // The contexts the gateway built carry the wire's words, not config
    // defaults: class default priority, wire budgets, SLO only for #3.
    let ctxs = g.backend().ctxs.clone();
    assert_eq!(ctxs[0].priority, Priority::High, "premium class default");
    assert_eq!(ctxs[3].priority, Priority::Batch, "wire priority wins");
    assert_eq!(ctxs[1].deadline, DeadlineSpec::Budget(Duration::from_millis(10)));
    assert_eq!(ctxs[2].deadline, DeadlineSpec::SloDefault);

    // Materialize through the SAME path the server uses and push into a
    // real EDF queue set, in submission order.
    let slo = Duration::from_millis(100);
    let mut qs = QueueSet::new(1, 8);
    for ctx in &ctxs {
        let req = ctx.into_request(
            ctx.trace_id,
            ShapeClass::batched_gemm(8, 8, 8),
            vec![],
            t0,
            slo,
        );
        qs.push(req).unwrap();
    }
    // EDF pops by wire deadline (priority breaking the 10 ms tie), with
    // the SLO-default request at its 100 ms slot — NOT submission order,
    // which would be 1, 2, 3, 4.
    let a = qs.pop_tenant(0).unwrap();
    assert_eq!((a.id, a.deadline), (2, t0 + Duration::from_millis(10)));
    let b = qs.pop_tenant(0).unwrap();
    assert_eq!((b.id, b.deadline), (4, t0 + Duration::from_millis(10)));
    let c = qs.pop_tenant(0).unwrap();
    assert_eq!((c.id, c.deadline), (3, t0 + slo), "SLO only when the wire named nothing");
    let d = qs.pop_tenant(0).unwrap();
    assert_eq!((d.id, d.deadline), (1, t0 + Duration::from_millis(300)));
}

#[test]
fn reactor_serves_the_full_stack_over_tcp() {
    use std::io::{BufRead, BufReader, Write};
    // One token of burst and a glacial refill: the second admitted
    // request must be rate limited no matter how slow the test host is.
    let gw = Arc::new(Mutex::new(Gateway::new(
        &cfg(vec![("key-0", 0, IsolationClass::Premium)], 0.001, 1.0),
        FakeShard::ok(),
    )));
    let handler = gateway_handler(gw.clone(), Arc::new(|_t| Vec::new()));
    let r = Reactor::start("127.0.0.1:0", 2, handler).expect("bind");
    let sock = std::net::TcpStream::connect(r.addr()).expect("connect");
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut w = sock;
    let mut ask = |line: &str| {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).expect("response json")
    };

    // An unknown priority is a validation error before any token is spent.
    let j = ask("{\"api_key\":\"key-0\",\"priority\":\"urgent\"}");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(
        j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
        Some("bad_request")
    );

    // The full stack admits a well-formed request and echoes the trace.
    let j = ask("{\"api_key\":\"key-0\",\"budget_ms\":25,\"priority\":\"high\",\"trace_id\":11}");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(j.get("tenant").and_then(Json::as_f64), Some(0.0));
    assert_eq!(j.get("trace_id").and_then(Json::as_f64), Some(11.0));

    // The bucket is empty: a structured rate-limit error with a retry hint.
    let j = ask("{\"api_key\":\"key-0\",\"trace_id\":12}");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    let err = j.get("error").expect("error body");
    assert_eq!(err.get("error").and_then(Json::as_str), Some("rate_limited"));
    assert_eq!(err.get("status").and_then(Json::as_f64), Some(429.0));
    assert!(err.get("retry_after_ms").and_then(Json::as_f64).unwrap() > 0.0);

    r.stop();
    let g = gw.lock().unwrap();
    assert_eq!(g.stats().admitted, 1);
    assert_eq!(g.stats().rate_limited, 1);
    // The wire's deadline/priority landed in the submitted context.
    let ctx = g.backend().ctxs[0];
    assert_eq!(ctx.deadline, DeadlineSpec::Budget(Duration::from_millis(25)));
    assert_eq!(ctx.priority, Priority::High);
    assert_eq!(ctx.trace_id, 11);
}

#[test]
fn hostile_budget_cannot_disable_the_gateway() {
    use std::io::{BufRead, BufReader, Write};
    let gw = Arc::new(Mutex::new(Gateway::new(
        &cfg(vec![("key-0", 0, IsolationClass::Standard)], 1000.0, 1000.0),
        FakeShard::ok(),
    )));
    let handler = gateway_handler(gw.clone(), Arc::new(|_t| Vec::new()));
    let r = Reactor::start("127.0.0.1:0", 2, handler).expect("bind");
    let sock = std::net::TcpStream::connect(r.addr()).expect("connect");
    sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut w = sock;
    let mut ask = |line: &str| {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("worker must answer, not die");
        Json::parse(resp.trim()).expect("response json")
    };

    // budget_ms:1e300 is finite and positive but far past the 24h
    // ceiling; it used to panic inside Duration::from_secs_f64 with the
    // gateway mutex held, poisoning it for every later request. Now it
    // is a structured bad_request...
    let j = ask("{\"api_key\":\"key-0\",\"budget_ms\":1e300,\"trace_id\":1}");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(
        j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
        Some("bad_request")
    );

    // ...and the gateway is still fully alive afterwards.
    let j = ask("{\"api_key\":\"key-0\",\"budget_ms\":25,\"trace_id\":2}");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(j.get("trace_id").and_then(Json::as_f64), Some(2.0));

    r.stop();
    let g = gw.lock().unwrap();
    assert_eq!(g.stats().bad_requests, 1);
    assert_eq!(g.stats().admitted, 1);
}
