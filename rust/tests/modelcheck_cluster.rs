//! Schedule-exhaustive model checking of the cluster tier's
//! sequencer → workers → committer ticket protocol.
//!
//! These tests instantiate the SAME generic
//! [`stgpu::coordinator::cluster::WorkerPool`] the production cluster
//! driver runs on `StdEnv` — but under [`ModelEnv`], where every channel
//! operation is a decision point for the DFS schedule explorer. The trunk
//! check asserts, on every interleaving:
//!
//! * no ticket is skipped or duplicated (the committed sequence is dense),
//! * no result commits before all of its predecessors,
//! * no worker or the committer gets stuck (the round always completes).
//!
//! The `mutation_*` tests re-introduce known-bad protocol variants and
//! assert the checker CATCHES them: journaling results in arrival order
//! (bypassing the reorder buffer), and issuing a ticket whose command is
//! never dispatched (the stalled-round deadlock).

use stgpu::coordinator::cluster::{
    InOrderCommitter, Sequencer, TicketRunner, Ticketed, WorkerPool,
};
use stgpu::coordinator::protocol::ProtoPayload;
use stgpu::util::modelcheck::{explore, CheckOpts, ModelEnv};

struct MCmd {
    ticket: u64,
}

impl ProtoPayload for MCmd {
    fn fingerprint(&self) -> u64 {
        self.ticket
    }
}

struct MRes {
    ticket: u64,
    node: usize,
}

impl ProtoPayload for MRes {
    fn fingerprint(&self) -> u64 {
        self.ticket
    }
}

impl Ticketed for MRes {
    fn ticket(&self) -> u64 {
        self.ticket
    }
}

/// The model node worker: yields between taking a command and reporting
/// its result — the window where a real node spends its round and where
/// reordering happens.
struct MNode {
    node: usize,
}

impl TicketRunner<MCmd, MRes> for MNode {
    fn run(&mut self, cmd: MCmd) -> MRes {
        ModelEnv::yield_now();
        MRes { ticket: cmd.ticket, node: self.node }
    }
}

// ---------------------------------------------------------------------------
// Trunk protocol check (must pass on every schedule)
// ---------------------------------------------------------------------------

#[test]
fn model_ticket_protocol_commits_dense_and_in_order() {
    // Three threads (driver + two node workers), two rounds. Preemption
    // bound 2 (CHESS-style): nearly all real concurrency bugs surface
    // within two preemptions.
    let opts = CheckOpts { max_preemptions: 2, ..CheckOpts::default() };
    let stats = explore("cluster-ticket-protocol", opts, || {
        let mut pool: WorkerPool<ModelEnv, MCmd, MRes> =
            WorkerPool::spawn(vec![MNode { node: 0 }, MNode { node: 1 }]);
        let mut seq = Sequencer::new();
        let mut com = InOrderCommitter::new();
        let mut committed: Vec<u64> = Vec::new();
        for _round in 0..2u64 {
            for node in 0..2usize {
                let t = seq.issue();
                assert!(pool.send(node, MCmd { ticket: t }), "live worker refused a command");
            }
            for _ in 0..2 {
                // A blocked recv here on any schedule == a stuck worker;
                // the checker's deadlock detector would report it.
                let r = pool.recv().expect("a worker exited mid-round");
                // The committer itself panics on skipped/duplicated
                // tickets; the assert pins the in-order release.
                for (t, _r) in com.offer(r.ticket(), r) {
                    assert_eq!(t, committed.len() as u64, "commit before a predecessor");
                    committed.push(t);
                }
            }
        }
        assert_eq!(
            committed,
            (0..4).collect::<Vec<u64>>(),
            "a ticket was skipped or duplicated"
        );
        assert_eq!(com.pending(), 0, "a result is stuck behind a missing predecessor");
        pool.shutdown();
        assert!(pool.recv().is_none(), "results channel closes after shutdown");
    })
    .unwrap_or_else(|f| panic!("{f}"));
    println!("cluster ticket protocol: {stats}");
    assert!(!stats.truncated, "exploration must be exhaustive");
    assert!(stats.schedules > 1);
}

// ---------------------------------------------------------------------------
// Mutation checks: known-bad variants the checker must catch
// ---------------------------------------------------------------------------

#[test]
fn mutation_commit_on_arrival_is_caught() {
    // Re-introduce the out-of-order-commit bug the InOrderCommitter
    // exists to prevent: journal each result as it ARRIVES. Arrival order
    // is a race between the two workers' sends on the shared results
    // channel, so some schedule delivers ticket 1 before ticket 0 — the
    // checker must find that schedule and report the violated assert.
    let err = explore("cluster-commit-on-arrival", CheckOpts::default(), || {
        let mut pool: WorkerPool<ModelEnv, MCmd, MRes> =
            WorkerPool::spawn(vec![MNode { node: 0 }, MNode { node: 1 }]);
        let mut seq = Sequencer::new();
        let mut committed: Vec<u64> = Vec::new();
        for node in 0..2usize {
            let t = seq.issue();
            assert!(pool.send(node, MCmd { ticket: t }));
        }
        for _ in 0..2 {
            let r = pool.recv().expect("workers alive");
            // BUG: no reorder buffer between the channel and the journal.
            assert_eq!(r.ticket(), committed.len() as u64, "commit out of ticket order");
            committed.push(r.ticket());
        }
        pool.shutdown();
    })
    .expect_err("the checker must find an arrival order that is not ticket order");
    assert!(err.message.contains("commit out of ticket order"), "got: {}", err.message);
    println!("commit-on-arrival caught after {} schedule(s)", err.schedules);
}

#[test]
fn mutation_skipped_ticket_stalls_the_round_and_is_caught() {
    // Re-introduce the skipped-ticket bug: the sequencer issues a ticket
    // whose command is never dispatched. The committer buffers every
    // later result waiting for the hole, and the driver blocks on a
    // result that can never arrive — the stalled-round deadlock the
    // "no stuck worker" property forbids.
    let err = explore("cluster-skipped-ticket", CheckOpts::default(), || {
        let mut pool: WorkerPool<ModelEnv, MCmd, MRes> =
            WorkerPool::spawn(vec![MNode { node: 0 }]);
        let mut seq = Sequencer::new();
        let mut com = InOrderCommitter::new();
        let _skipped = seq.issue(); // BUG: issued, never sent to any worker
        let t1 = seq.issue();
        assert!(pool.send(0, MCmd { ticket: t1 }));
        let r = pool.recv().expect("worker alive");
        assert!(com.offer(r.ticket(), r).is_empty(), "t1 must buffer behind the hole");
        // Wait for the predecessor that was never dispatched.
        let _ = pool.recv();
    })
    .expect_err("the checker must catch the stalled round");
    assert!(err.message.contains("deadlock"), "got: {}", err.message);
    println!("skipped ticket caught after {} schedule(s)", err.schedules);
}
