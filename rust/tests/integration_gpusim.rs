//! Integration: the V100 simulator reproduces the *shapes* of the paper's
//! findings — who wins, by roughly what factor, where the walls fall.
//! (Absolute numbers live in the benches; these tests pin the orderings the
//! paper's figures depend on.)

use stgpu::gpusim::memory::{max_replicas, DeploymentShape};
use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::models::zoo;
use stgpu::workload::{model_tenants, sgemm_tenants};

fn throughput(policy: Policy, tenants: usize, shape: GemmShape) -> f64 {
    let cfg = SimConfig::new(DeviceSpec::v100(), policy);
    let report = gpusim::run(&cfg, &sgemm_tenants(tenants, 30, shape));
    report.throughput_flops()
}

#[test]
fn spacetime_beats_space_beats_time_at_conv2_2() {
    // Figure 7 ordering at the paper's conv2_2 shape.
    let shape = GemmShape::RESNET18_CONV2_2;
    for r in [10, 20, 60] {
        let st = throughput(Policy::SpaceTime { max_batch: 64 }, r, shape);
        let sp = throughput(Policy::SpaceMuxMps { anomaly_seed: 1 }, r, shape);
        let tm = throughput(Policy::TimeMux, r, shape);
        assert!(st > sp, "R={r}: space-time {st:.3e} must beat space {sp:.3e}");
        assert!(sp > tm, "R={r}: space {sp:.3e} must beat time {tm:.3e}");
    }
}

#[test]
fn spacetime_speedup_over_space_is_multiple_x() {
    // Paper: 3.23x over space-only at conv2_2 (geomean 2 <= R <= 120).
    let shape = GemmShape::RESNET18_CONV2_2;
    let mut ratios = Vec::new();
    for r in [10usize, 20, 40, 80, 120] {
        let st = throughput(Policy::SpaceTime { max_batch: 128 }, r, shape);
        let sp = throughput(Policy::SpaceMuxMps { anomaly_seed: 1 }, r, shape);
        ratios.push(st / sp);
    }
    let geomean = stgpu::util::stats::geomean(&ratios);
    assert!(
        geomean > 2.0 && geomean < 6.0,
        "conv2_2 space-time/space geomean {geomean:.2} out of paper-shaped band"
    );
}

#[test]
fn time_mux_slowdown_grows_linearly() {
    // Figure 3: time multiplexing latency degrades roughly linearly in the
    // number of tenants.
    let shape = GemmShape::RESNET18_CONV2_2;
    let mean_latency = |n: usize| {
        let cfg = SimConfig::new(DeviceSpec::v100(), Policy::TimeMux);
        gpusim::run(&cfg, &sgemm_tenants(n, 20, shape)).mean_latency()
    };
    let l2 = mean_latency(2);
    let l8 = mean_latency(8);
    let l16 = mean_latency(16);
    let r8 = l8 / l2; // ≈ 4 for linear scaling
    let r16 = l16 / l2; // ≈ 8
    assert!((2.5..6.0).contains(&r8), "8/2 tenant latency ratio {r8:.2}");
    assert!((5.0..12.0).contains(&r16), "16/2 tenant latency ratio {r16:.2}");
}

#[test]
fn exclusive_is_the_latency_floor() {
    let shape = GemmShape::SQUARE_256;
    let run = |p: Policy| {
        let cfg = SimConfig::new(DeviceSpec::v100(), p);
        gpusim::run(&cfg, &sgemm_tenants(6, 20, shape)).mean_latency()
    };
    let excl = run(Policy::Exclusive);
    for p in [
        Policy::TimeMux,
        Policy::SpaceMuxMps { anomaly_seed: 3 },
        Policy::SpaceMuxStreams,
    ] {
        let l = run(p.clone());
        assert!(
            l >= excl * 0.99,
            "{}: latency {l:.3e} below exclusive floor {excl:.3e}",
            p.label()
        );
    }
}

#[test]
fn mps_straggler_gap_within_paper_band() {
    // Figure 4: up to ~25% fastest-vs-slowest gap under MPS; worse for odd
    // tenant counts.
    let shape = GemmShape::RESNET18_CONV2_2;
    let gap = |n: usize| {
        let cfg = SimConfig::new(
            DeviceSpec::v100(),
            Policy::SpaceMuxMps { anomaly_seed: 7 },
        );
        gpusim::run(&cfg, &sgemm_tenants(n, 20, shape)).straggler_gap()
    };
    let g_even = gap(8);
    let g_odd = gap(9);
    assert!(g_even >= 0.0 && g_even <= 0.30, "even gap {g_even:.3}");
    assert!(g_odd <= 0.30, "odd gap {g_odd:.3}");
    assert!(g_odd >= g_even, "odd tenant counts amplify the anomaly");
    // Streams (no MPS proxy) shows no anomaly gap.
    let cfg = SimConfig::new(DeviceSpec::v100(), Policy::SpaceMuxStreams);
    let g_streams = gpusim::run(&cfg, &sgemm_tenants(8, 20, shape)).straggler_gap();
    assert!(g_streams < g_even.max(0.02), "streams gap {g_streams:.3}");
}

#[test]
fn memory_wall_matches_figure5() {
    // Figure 5: process-per-replica hits the 16 GB wall around 18 ResNet-50
    // replicas; explicit streams scale to at least 60.
    let spec = DeviceSpec::v100();
    let resnet50 = zoo::resnet50();
    let fp = resnet50.footprint(26);
    let wall_proc = max_replicas(&spec, DeploymentShape::ProcessPerReplica, &fp);
    let wall_streams = max_replicas(&spec, DeploymentShape::SharedProcessStreams, &fp);
    assert!(
        (14..=22).contains(&wall_proc),
        "process-per-replica wall {wall_proc} (paper: 18)"
    );
    assert!(wall_streams >= 60, "streams wall {wall_streams} (paper: >= 60)");
}

#[test]
fn superkernel_reduces_launch_count() {
    // Figure 6's point: space-time collapses R launches into ~R/max_batch.
    let shape = GemmShape::SQUARE_256;
    let n = 32;
    let cfg_st = SimConfig::new(DeviceSpec::v100(), Policy::SpaceTime { max_batch: 64 });
    let st = gpusim::run(&cfg_st, &sgemm_tenants(n, 10, shape));
    let cfg_sp = SimConfig::new(DeviceSpec::v100(), Policy::SpaceMuxStreams);
    let sp = gpusim::run(&cfg_sp, &sgemm_tenants(n, 10, shape));
    assert!(st.superkernel_launches > 0);
    assert!(
        st.superkernel_launches * 8 <= sp.kernel_launches,
        "super-kernels {} should be far fewer than stream launches {}",
        st.superkernel_launches,
        sp.kernel_launches
    );
    assert_eq!(st.fused_problems, (n as u64) * 10);
}

#[test]
fn model_workloads_complete_under_all_policies() {
    // Figure 3 macro-workload: MobileNetV2 + ResNet-50 replicas complete
    // every inference under every policy (conservation).
    for model in [zoo::mobilenet_v2(), zoo::resnet50()] {
        let workloads = model_tenants(4, 3, &model, 4);
        for policy in [
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 5 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 32 },
        ] {
            let cfg = SimConfig::new(DeviceSpec::v100(), policy.clone());
            let report = gpusim::run(&cfg, &workloads);
            assert_eq!(
                report.total_completed(),
                4 * 3,
                "{} on {}: lost inferences",
                policy.label(),
                model.name
            );
            assert!(report.makespan > 0.0);
        }
    }
}

#[test]
fn throughput_never_exceeds_device_peak() {
    let spec = DeviceSpec::v100();
    let peak = spec.peak_flops();
    for policy in [
        Policy::Exclusive,
        Policy::TimeMux,
        Policy::SpaceMuxStreams,
        Policy::SpaceTime { max_batch: 64 },
    ] {
        let cfg = SimConfig::new(spec.clone(), policy);
        let report = gpusim::run(&cfg, &sgemm_tenants(16, 20, GemmShape::SQUARE_256));
        assert!(
            report.throughput_flops() <= peak * 1.001,
            "{}: {:.3e} exceeds peak {:.3e}",
            cfg.policy.label(),
            report.throughput_flops(),
            peak
        );
    }
}

#[test]
fn figure1_lineup_latency_grows_with_model_year() {
    // Figure 1's trend: CPU batch-1 latency increases across generations;
    // SENet-154 ≈ 4.1 s on CPU.
    let cpu = DeviceSpec::cpu_xeon();
    let mut latencies = Vec::new();
    for model in zoo::figure1_lineup() {
        let cfg = SimConfig::new(cpu.clone(), Policy::Exclusive);
        let report = gpusim::run(&cfg, &model_tenants(1, 1, &model, 1));
        latencies.push((model.name.clone(), report.mean_latency()));
    }
    // NB: exact match — "densenet121".contains("senet") is true!
    let alexnet = latencies.iter().find(|(n, _)| n == "alexnet").unwrap().1;
    let senet = latencies.iter().find(|(n, _)| n == "senet154").unwrap().1;
    assert!(senet > alexnet * 10.0, "senet {senet:.2}s vs alexnet {alexnet:.2}s");
    assert!(
        (2.0..8.0).contains(&senet),
        "senet CPU latency {senet:.2}s (paper: ~4.1 s)"
    );
}
