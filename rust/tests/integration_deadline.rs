//! Integration: the deadline-aware (EDF) scheduling path — cost-model
//! predictions, EDF drain order, launch splitting to protect urgent
//! deadlines, and admission-time infeasibility shedding
//! (`Reject::DeadlineInfeasible`, 504-style).
//!
//! Pure logic (no PJRT artifacts) except the final end-to-end test, which
//! skips without `artifacts/`.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::{
    make_scheduler_deadline_aware, Coordinator, CostModel, InferenceRequest,
    PaddingPolicy, Priority, QueueSet, Reject, Scheduler, ShapeClass,
};
use stgpu::util::prng::Rng;

const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 };

fn req(id: u64, tenant: usize, now: Instant, slo_ms: u64) -> InferenceRequest {
    InferenceRequest {
        id,
        tenant,
        class: CLASS,
        payload: vec![],
        arrived: now,
        deadline: now + Duration::from_millis(slo_ms),
        priority: Priority::Normal,
        trace_id: 0,
    }
}

#[test]
fn edf_planner_protects_urgent_deadlines_by_splitting() {
    // Hand-calibrated predictor: an 8-wide fused launch takes 100 ms, a
    // 4-wide 10 ms — so an 8-wide launch with a 20 ms deadline member MUST
    // split, and the urgent half must go first.
    let mut cm = CostModel::new();
    cm.observe(CLASS, 8, 0.100);
    cm.observe(CLASS, 4, 0.010);
    let cost = Arc::new(Mutex::new(cm));
    let mut sched = make_scheduler_deadline_aware(
        SchedulerKind::SpaceTime,
        vec![1, 2, 4, 8, 16, 32, 64],
        8,
        PaddingPolicy::PadToBucket,
        cost,
        0.0,
    );
    let now = Instant::now();
    let mut q = QueueSet::new(8, 16);
    for t in 0..8usize {
        let slo_ms = if t < 4 { 20 } else { 10_000 };
        q.push(req(t as u64, t, now, slo_ms)).unwrap();
    }
    let plan = sched.plan_round_at(&mut q, now);
    assert_eq!(plan.drained, 8);
    assert_eq!(plan.deadline_splits, 1, "100 ms fused launch vs 20 ms deadline");
    assert_eq!(plan.launches.len(), 2);
    let first = &plan.launches[0];
    assert_eq!(first.r_bucket, 4, "re-bucketed to the feasible prefix");
    assert!(
        first.entries.iter().all(|e| e.tenant < 4),
        "urgent tenants launch first: {:?}",
        first.entries.iter().map(|e| e.tenant).collect::<Vec<_>>()
    );
    let total: usize = plan.launches.iter().map(|l| l.entries.len()).sum();
    assert_eq!(total, 8, "splitting conserves requests");
    assert!(q.is_empty());
}

#[test]
fn baselines_ignore_deadlines_and_never_split() {
    // The §3 baselines stay FIFO even when built through the deadline-aware
    // factory (they fall back to the plain constructor).
    let cost = Arc::new(Mutex::new(CostModel::new()));
    for kind in [SchedulerKind::Exclusive, SchedulerKind::TimeMux, SchedulerKind::SpaceMux]
    {
        let mut sched = make_scheduler_deadline_aware(
            kind,
            vec![1, 2, 4, 8],
            8,
            PaddingPolicy::PadToBucket,
            cost.clone(),
            0.0,
        );
        let now = Instant::now();
        let mut q = QueueSet::new(2, 16);
        // Tenant 1 is far more urgent, but FIFO rotation starts at tenant 0.
        q.push(req(0, 0, now, 10_000)).unwrap();
        q.push(req(1, 1, now, 1)).unwrap();
        let plan = sched.plan_round_at(&mut q, now);
        assert_eq!(plan.deadline_splits, 0, "{kind:?} must not split");
        assert!(!plan.launches.is_empty());
        assert_eq!(
            plan.launches[0].entries[0].tenant, 0,
            "{kind:?} drains FIFO, not EDF"
        );
    }
}

#[test]
fn admission_feasibility_check_and_status_code() {
    let cm = CostModel::new();
    let min = cm.predict(CLASS, 1);
    assert!(min > 0.0);
    // An SLO below the minimal-launch prediction is lost before it queues.
    assert!(cm.deadline_infeasible(CLASS, min * 0.5, 0.0));
    assert!(!cm.deadline_infeasible(CLASS, min * 100.0, 0.0));
    // Slack is honored: a barely-feasible SLO flips once slack eats it.
    assert!(cm.deadline_infeasible(CLASS, min * 1.1, min));
    assert_eq!(Reject::DeadlineInfeasible.http_status(), 504);
}

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn coordinator_sheds_deadline_infeasible_at_admission() {
    // End-to-end (needs artifacts): a tenant whose SLO is below any
    // conceivable launch duration is shed at `submit` with
    // `Reject::DeadlineInfeasible`; a same-class tenant with a sane SLO is
    // admitted, served, and gets an attainment verdict.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        edf: true,
        artifacts_dir: dir,
        tenants: vec![
            TenantConfig {
                name: "hopeless".into(),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 1e-6, // 1 ns: below any launch prediction
                weight_seed: 0,
            },
            TenantConfig {
                name: "fine".into(),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: 1,
            },
        ],
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    assert!(coord.deadline_aware());
    let mut rng = Rng::new(7);
    let payload = coord.random_payload(0, &mut rng);
    assert_eq!(coord.submit(0, payload), Err(Reject::DeadlineInfeasible));
    let payload = coord.random_payload(1, &mut rng);
    assert!(coord.submit(1, payload).is_ok());
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 1);
    assert!(
        coord.monitor().attainment(1).is_some(),
        "served tenant gets a deadline verdict"
    );
    // The shard's predictor was fed the measured launch.
    let cm = coord.cost_model(0).expect("EDF coordinator has a cost model");
    assert!(cm.lock().unwrap().observations() >= 1);
}
