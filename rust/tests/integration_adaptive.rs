//! Integration: the adaptive space-time controller.
//!
//! Artifact-free halves: round-tag conservation through
//! [`LanePool::resize`] under randomized mid-stream reconfigurations
//! (the controller's primitive must never lose a completion), and the
//! controller's dwell/bounds properties (unit-tested in
//! `coordinator::controller`, re-exercised here through the public API).
//!
//! Artifact-gated halves (skip without `make artifacts`): a config with
//! `[controller] adaptive = false` reproduces the pre-controller
//! coordinator bit-for-bit (same responses, same counters as a config
//! with no `[controller]` section at all), and an `adaptive = true`
//! coordinator serves losslessly while exporting its decision in the
//! device snapshot.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use stgpu::config::{ControllerConfig, SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::lanepool::{LanePool, LaunchExecutor, WorkItem};
use stgpu::coordinator::{
    Coordinator, InferenceRequest, Launch, LaunchResult, ModelSpec, Priority, ShapeClass,
};
use stgpu::runtime::HostTensor;
use stgpu::util::prng::Rng;
use stgpu::util::prop::run_prop;

const CLASS: ShapeClass = ShapeClass { kind: "batched_gemm", m: 8, n: 8, k: 8 };

fn item(round: u64, index: usize, lane: usize, lanes_resident: usize) -> WorkItem {
    let now = Instant::now();
    WorkItem {
        round,
        index,
        lane,
        lanes_resident,
        launch: Launch {
            class: CLASS,
            entries: vec![InferenceRequest {
                id: round * 1000 + index as u64,
                tenant: 0,
                class: CLASS,
                payload: vec![],
                arrived: now,
                deadline: now,
                priority: Priority::Normal,
                trace_id: 0,
            }],
            r_bucket: 1,
        },
        spec: ModelSpec::Sgemm { m: 8, n: 8, k: 8 },
        weights: None,
        weights_marshal_s: 0.0,
        cost_hint: 0.0,
        executed_lane: lane,
        stolen: false,
        attempt: 0,
    }
}

/// Executor with a small deterministic delay so resizes race in-flight
/// items (instant executors would drain before the resize lands).
struct SpinExec;
impl LaunchExecutor for SpinExec {
    fn execute(&self, item: &WorkItem) -> anyhow::Result<LaunchResult> {
        let t0 = Instant::now();
        while t0.elapsed() < std::time::Duration::from_micros(200) {
            std::hint::spin_loop();
        }
        Ok(LaunchResult {
            outputs: Vec::new(),
            service_s: 1e-6,
            marshal_s: 0.0,
            r_bucket: item.launch.r_bucket,
        })
    }
}

#[test]
fn prop_resize_mid_stream_conserves_round_tagged_completions() {
    // The ISSUE's resize property: random interleavings of dispatch
    // bursts and pool resizes lose no completion, and every completion
    // still carries the lane count ITS round was dispatched with — even
    // when that round's lanes have since been retired.
    run_prop("lanepool resize conservation", 0xAD2E, 12, |rng| {
        let mut pool = LanePool::new(1 + rng.gen_range(4) as usize, Arc::new(SpinExec));
        let mut planned: HashMap<u64, (usize, usize)> = HashMap::new();
        let mut dispatched_total = 0usize;
        for round in 1..=(4 + rng.gen_range(6)) {
            // Resize to a random width between bursts (grow and shrink).
            let width = 1 + rng.gen_range(5) as usize;
            pool.resize(width);
            assert_eq!(pool.lanes(), width);
            let launches = 1 + rng.gen_range(6) as usize;
            for i in 0..launches {
                pool.dispatch(item(round, i, i % width, width));
            }
            planned.insert(round, (width, launches));
            dispatched_total += launches;
            // Sometimes collect a few mid-stream, sometimes let them pile
            // across the next resize.
            if rng.gen_bool(0.5) {
                for _ in 0..rng.gen_range(launches as u64 + 1) {
                    let c = pool.collect().unwrap();
                    assert_eq!(c.lanes_resident, planned[&c.round].0);
                    dispatched_total -= 1;
                }
            }
        }
        while dispatched_total > 0 {
            let c = pool.collect().unwrap();
            assert_eq!(
                c.lanes_resident, planned[&c.round].0,
                "round {} lost its tag across resizes",
                c.round
            );
            assert!(c.result.is_ok());
            dispatched_total -= 1;
        }
        assert_eq!(pool.in_flight(), 0, "zero lost completions");
        let leftover = pool.shutdown();
        assert!(leftover.is_empty());
    });
}

// ---------------------------------------------------------------------------
// Artifact-gated: full-coordinator behavior.
// ---------------------------------------------------------------------------

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn config(controller: Option<ControllerConfig>) -> Option<ServerConfig> {
    let dir = artifacts_dir()?;
    Some(ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        // Serial, single-lane, single-device: the deterministic baseline
        // the bit-for-bit comparison needs (mirrors integration_pipeline).
        lanes: 1,
        pipeline_depth: 1,
        artifacts_dir: dir,
        controller: controller.unwrap_or_default(),
        tenants: (0..4)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    })
}

/// Run seeded submit/drain waves; returns responses sorted by id plus the
/// counters the comparison pins.
#[allow(clippy::type_complexity)]
fn run_waves(
    coord: &mut Coordinator,
    waves: usize,
) -> (Vec<(u64, usize, usize, HostTensor)>, Vec<(u64, u64, u64)>) {
    let n = coord.tenants.len();
    let mut rng = Rng::new(0xADA);
    let mut out = Vec::new();
    for _ in 0..waves {
        for t in 0..n {
            for _ in 0..2 {
                let payload = coord.random_payload(t, &mut rng);
                coord.submit(t, payload).unwrap();
            }
        }
        for r in coord.run_until_drained().unwrap() {
            out.push((r.id, r.tenant, r.fused_r, r.output));
        }
    }
    out.sort_by_key(|(id, ..)| *id);
    let counters = coord
        .device_snapshots()
        .iter()
        .map(|d| (d.launches, d.superkernel_launches, d.drained))
        .collect();
    (out, counters)
}

#[test]
fn adaptive_false_reproduces_the_static_coordinator_bit_for_bit() {
    let Some(cfg_plain) = config(None) else { return };
    let Some(cfg_off) = config(Some(ControllerConfig {
        adaptive: false,
        // Non-default knobs must be inert while adaptive is off.
        dwell_rounds: 2,
        max_lanes: 4,
        max_depth: 2,
        ..Default::default()
    })) else {
        return;
    };
    let mut plain = Coordinator::new(&cfg_plain).unwrap();
    let mut off = Coordinator::new(&cfg_off).unwrap();
    assert!(!plain.adaptive());
    assert!(!off.adaptive(), "adaptive=false must construct no controller");
    let (rp, cp) = run_waves(&mut plain, 3);
    let (ro, co) = run_waves(&mut off, 3);
    assert_eq!(cp, co, "per-device counters must match bit-for-bit");
    assert_eq!(rp.len(), ro.len());
    for ((id_p, t_p, f_p, out_p), (id_o, t_o, f_o, out_o)) in rp.iter().zip(&ro) {
        assert_eq!((id_p, t_p, f_p), (id_o, t_o, f_o));
        assert_eq!(out_p.shape, out_o.shape);
        assert_eq!(out_p.data, out_o.data, "outputs must be bit-identical");
    }
    // Snapshot export: controller fields read as static/off.
    let snap = off.device_snapshots();
    assert!(!snap[0].ctrl_adaptive);
    assert_eq!(snap[0].ctrl_lanes, 1);
    assert_eq!(snap[0].ctrl_depth, 1);
    assert_eq!(snap[0].ctrl_reconfigs, 0);
    assert!(snap[0].ctrl_utilities.is_empty());
}

#[test]
fn adaptive_coordinator_serves_losslessly_and_exports_decisions() {
    let Some(cfg) = config(Some(ControllerConfig {
        adaptive: true,
        dwell_rounds: 2,
        max_lanes: 2,
        max_depth: 2,
        ..Default::default()
    })) else {
        return;
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    assert!(coord.adaptive());
    let (lanes0, depth0) = coord.resident(0).unwrap();
    assert_eq!((lanes0, depth0), (1, 1), "starts at the static knobs");
    let n = coord.tenants.len();
    let mut rng = Rng::new(0xADB);
    let mut submitted = 0u64;
    let mut completed = 0u64;
    for _ in 0..8 {
        for t in 0..n {
            for _ in 0..3 {
                let payload = coord.random_payload(t, &mut rng);
                coord.submit(t, payload).unwrap();
                submitted += 1;
            }
        }
        completed += coord.run_until_drained().unwrap().len() as u64;
    }
    assert_eq!(completed, submitted, "reconfigurations must lose nothing");
    let (lanes, depth) = coord.resident(0).unwrap();
    assert!((1..=2).contains(&lanes), "decision within [1, max_lanes]");
    assert!((1..=2).contains(&depth), "decision within [1, max_depth]");
    let snap = coord.snapshot();
    let d0 = &snap.devices[0];
    assert!(d0.ctrl_adaptive);
    assert_eq!(d0.ctrl_lanes as usize, lanes);
    assert_eq!(d0.ctrl_depth as usize, depth);
    assert!(d0.ctrl_evals > 0, "dwell windows with traffic must evaluate");
    assert_eq!(
        d0.ctrl_utilities.len(),
        2,
        "one utility per candidate lane count"
    );
    // Status JSON carries the controller section.
    let json = snap.to_json().to_string();
    let back = stgpu::util::json::Json::parse(&json).unwrap();
    let dev = &back.get("devices").unwrap().as_arr().unwrap()[0];
    assert!(matches!(
        dev.get("ctrl_adaptive"),
        Some(stgpu::util::json::Json::Bool(true))
    ));
    assert!(dev.get("ctrl_utility").is_some());
}
