//! Integration: the coordinator over real PJRT artifacts — every scheduler
//! produces correct numerics, cross-tenant fusion happens for space-time,
//! and the eviction path drains cleanly.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::{Coordinator, Flavor, Reject};
use stgpu::runtime::{host_batched_gemm, HostTensor};
use stgpu::util::prng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn config(scheduler: SchedulerKind, n_tenants: usize, model: &str) -> Option<ServerConfig> {
    let dir = artifacts_dir()?;
    Some(ServerConfig {
        scheduler,
        artifacts_dir: dir,
        tenants: (0..n_tenants)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: model.into(),
                batch: 1,
                slo_ms: 1000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    })
}

/// Submit `per_tenant` random sgemm requests per tenant; return payload copies
/// keyed by request id for post-hoc verification.
fn submit_sgemm(
    coord: &mut Coordinator,
    per_tenant: usize,
    rng: &mut Rng,
) -> Vec<(u64, usize, Vec<HostTensor>)> {
    let n = coord.tenants.len();
    let mut sent = Vec::new();
    for round in 0..per_tenant {
        for t in 0..n {
            let payload = coord.random_payload(t, rng);
            let id = coord.submit(t, payload.clone()).unwrap();
            let _ = round;
            sent.push((id, t, payload));
        }
    }
    sent
}

fn verify_sgemm(sent: &[(u64, usize, Vec<HostTensor>)], responses: &[stgpu::coordinator::InferenceResponse]) {
    for (id, _tenant, payload) in sent {
        let resp = responses
            .iter()
            .find(|r| r.id == *id)
            .unwrap_or_else(|| panic!("no response for request {id}"));
        let a = HostTensor::stack(&[&payload[0]], 1);
        let b = HostTensor::stack(&[&payload[1]], 1);
        let want = host_batched_gemm(&a, &b).slice_problem(0);
        let diff = resp.output.max_abs_diff(&want);
        assert!(diff < 1e-2, "request {id}: diff {diff}");
    }
}

#[test]
fn space_time_fuses_and_computes_correctly() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 4, "sgemm:64x32x48") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(1);
    let sent = submit_sgemm(&mut coord, 2, &mut rng);
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 8);
    // All 8 same-class problems fused into one launch (bucket 8).
    assert!(
        responses.iter().all(|r| r.fused_r == 8),
        "expected every response fused at R=8, got {:?}",
        responses.iter().map(|r| r.fused_r).collect::<Vec<_>>()
    );
    verify_sgemm(&sent, &responses);
    let snap = coord.snapshot();
    assert_eq!(snap.superkernel_launches, 1);
    assert_eq!(snap.total_completed(), 8);
}

#[test]
fn time_mux_serializes_one_problem_per_launch() {
    let Some(cfg) = config(SchedulerKind::TimeMux, 3, "sgemm:64x32x48") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(2);
    let sent = submit_sgemm(&mut coord, 2, &mut rng);
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 6);
    assert!(responses.iter().all(|r| r.fused_r == 1));
    verify_sgemm(&sent, &responses);
    let snap = coord.snapshot();
    assert_eq!(snap.kernel_launches, 6, "six singleton launches");
    assert_eq!(snap.superkernel_launches, 0);
}

#[test]
fn space_mux_matches_oracle_too() {
    let Some(cfg) = config(SchedulerKind::SpaceMux, 3, "sgemm:64x32x48") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(3);
    let sent = submit_sgemm(&mut coord, 2, &mut rng);
    let responses = coord.run_until_drained().unwrap();
    verify_sgemm(&sent, &responses);
    assert!(responses.iter().all(|r| r.fused_r == 1));
}

#[test]
fn exclusive_batches_within_tenant_only() {
    let Some(cfg) = config(SchedulerKind::Exclusive, 2, "sgemm:64x32x48") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(4);
    let sent = submit_sgemm(&mut coord, 4, &mut rng);
    let responses = coord.run_until_drained().unwrap();
    verify_sgemm(&sent, &responses);
    // 2 tenants × 4 requests → 2 launches of R=4 (single-tenant batches).
    assert!(responses.iter().all(|r| r.fused_r == 4));
    assert_eq!(coord.snapshot().superkernel_launches, 2);
}

#[test]
fn mlp_tenants_use_their_own_weights() {
    // Two mlp tenants with different weight seeds fused into one launch
    // must produce DIFFERENT outputs for the SAME input — per-lane weights
    // are per-tenant (disjoint models in one super-kernel).
    let Some(cfg) = config(SchedulerKind::SpaceTime, 2, "mlp") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(5);
    let x = coord.random_payload(0, &mut rng);
    coord.submit(0, x.clone()).unwrap();
    coord.submit(1, x.clone()).unwrap();
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 2);
    assert_eq!(responses[0].fused_r, 2, "both fused in one launch");
    let d = responses[0].output.max_abs_diff(&responses[1].output);
    assert!(d > 1e-3, "different weights must give different outputs (d={d})");
}

#[test]
fn mlp_output_matches_host_oracle() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 1, "mlp") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(6);
    let payload = coord.random_payload(0, &mut rng);
    coord.submit(0, payload.clone()).unwrap();
    let responses = coord.run_until_drained().unwrap();
    let w = &coord.tenants.get(0).unwrap().weights;
    let x = HostTensor::stack(&[&payload[0]], 1);
    let w1 = HostTensor::stack(&[&w[0]], 1);
    let b1 = HostTensor::stack(&[&w[1]], 1);
    let w2 = HostTensor::stack(&[&w[2]], 1);
    let h = stgpu::runtime::host_fused_linear(&x, &w1, &b1);
    let want = host_batched_gemm(&h, &w2).slice_problem(0);
    let diff = responses[0].output.max_abs_diff(&want);
    assert!(diff < 1e-2, "mlp diff {diff}");
}

#[test]
fn fused_linear_serves_and_matches_oracle() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 2, "fused_linear") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(21);
    let payload = coord.random_payload(0, &mut rng);
    coord.submit(0, payload.clone()).unwrap();
    coord.submit(1, coord.random_payload(1, &mut rng)).unwrap();
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 2);
    assert_eq!(responses[0].fused_r, 2, "cross-tenant fused");
    let r0 = responses.iter().find(|r| r.tenant == 0).unwrap();
    assert_eq!(r0.output.shape, vec![8, 256]);
    assert!(r0.output.data.iter().all(|&v| v >= 0.0), "relu clamps");
    // Oracle for tenant 0.
    let w = &coord.tenants.get(0).unwrap().weights;
    let want = stgpu::runtime::host_fused_linear(
        &HostTensor::stack(&[&payload[0]], 1),
        &HostTensor::stack(&[&w[0]], 1),
        &HostTensor::stack(&[&w[1]], 1),
    )
    .slice_problem(0);
    assert!(r0.output.max_abs_diff(&want) < 1e-2);
}

#[test]
fn rnn_cell_outputs_bounded_by_tanh() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 2, "rnn_cell") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(7);
    for t in 0..2 {
        let p = coord.random_payload(t, &mut rng);
        coord.submit(t, p).unwrap();
    }
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert_eq!(r.output.shape, vec![512, 1]);
        assert!(r.output.data.iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }
}

#[test]
fn pallas_flavor_serves_identically() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 2, "sgemm:64x32x48") else { return };
    let mut rng = Rng::new(8);
    let mut coord_x = Coordinator::with_flavor(&cfg, Flavor::Xla).unwrap();
    let sent = submit_sgemm(&mut coord_x, 1, &mut rng);
    let rx = coord_x.run_until_drained().unwrap();

    let mut coord_p = Coordinator::with_flavor(&cfg, Flavor::Pallas).unwrap();
    for (_, t, payload) in &sent {
        coord_p.submit(*t, payload.clone()).unwrap();
    }
    let rp = coord_p.run_until_drained().unwrap();
    for (a, b) in rx.iter().zip(&rp) {
        let d = a.output.max_abs_diff(&b.output);
        assert!(d < 1e-3, "xla vs pallas serving diff {d}");
    }
}

#[test]
fn submit_validates_payload_shapes() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 1, "sgemm:64x32x48") else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    // Wrong tensor count.
    assert!(matches!(
        coord.submit(0, vec![HostTensor::zeros(&[64, 48])]),
        Err(Reject::BadRequest(_))
    ));
    // Wrong shape.
    assert!(matches!(
        coord.submit(
            0,
            vec![HostTensor::zeros(&[64, 48]), HostTensor::zeros(&[48, 33])]
        ),
        Err(Reject::BadRequest(_))
    ));
    // Unknown tenant.
    assert!(matches!(coord.submit(9, vec![]), Err(Reject::BadRequest(_))));
}

#[test]
fn queue_depth_backpressures() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        artifacts_dir: dir,
        queue_depth: 2,
        tenants: vec![TenantConfig {
            name: "t0".into(),
            model: "sgemm:64x32x48".into(),
            batch: 1,
            slo_ms: 1000.0,
            weight_seed: 0,
        }],
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(9);
    let p = coord.random_payload(0, &mut rng);
    coord.submit(0, p.clone()).unwrap();
    coord.submit(0, p.clone()).unwrap();
    assert_eq!(coord.submit(0, p.clone()), Err(Reject::QueueFull));
    // Draining frees capacity.
    coord.run_until_drained().unwrap();
    assert!(coord.submit(0, p).is_ok());
}

#[test]
fn warmup_covers_tenant_kinds() {
    let Some(cfg) = config(SchedulerKind::SpaceTime, 2, "mlp") else { return };
    let coord = Coordinator::new(&cfg).unwrap();
    let n = coord.warmup().unwrap();
    assert_eq!(n, 7, "mlp_block xla artifacts across 7 R buckets");
    // After warmup the serving path never compiles.
    let before = coord.engine().stats().compiles;
    assert_eq!(before as usize, n);
}
