//! Failure injection: the coordinator must degrade gracefully — stragglers
//! get evicted without collateral damage, overloaded queues reject instead
//! of growing, evicted tenants' in-queue requests fail crisply, and the
//! system keeps serving healthy tenants throughout.
//!
//! PJRT-dependent tests require `make artifacts` (skips otherwise);
//! monitor-level injections run pure.

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::{
    Coordinator, Health, MonitorConfig, Reject, SloMonitor, TenantRegistry,
};
use stgpu::util::prng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built");
        None
    }
}

// ---------------------------------------------------------------------------
// Pure monitor-level injections (no PJRT)
// ---------------------------------------------------------------------------

fn registry(n: usize) -> TenantRegistry {
    let mut reg = TenantRegistry::new();
    for i in 0..n {
        reg.register(&format!("t{i}"), "sgemm:64x64x64", 100.0, i as u64)
            .unwrap();
    }
    reg
}

#[test]
fn injected_mps_straggler_is_evicted_and_system_recovers() {
    // Model the paper's Figure 4 anomaly: one tenant runs 25% slow. The
    // monitor evicts exactly that tenant; throughput of the rest is intact.
    let mut reg = registry(8);
    let mut mon = SloMonitor::new(
        MonitorConfig { threshold: 1.15, strikes: 3, ..Default::default() },
        &reg,
    );
    let straggler = 5usize;
    for _window in 0..6 {
        for t in 0..8 {
            for _ in 0..4 {
                let base = 2e-3;
                mon.observe(t, if t == straggler { base * 1.25 } else { base });
            }
        }
        mon.check(&mut reg);
    }
    assert_eq!(reg.get(straggler).unwrap().health, Health::Evicted);
    assert_eq!(reg.evicted_count(), 1, "only the straggler is evicted");
    assert_eq!(reg.servable().count(), 7);
}

#[test]
fn transient_blip_does_not_evict() {
    // A single slow window (GC pause-style) must not trigger eviction if
    // the tenant recovers before accumulating `strikes`.
    let mut reg = registry(4);
    let mut mon = SloMonitor::new(
        MonitorConfig { threshold: 1.15, strikes: 3, ..Default::default() },
        &reg,
    );
    // Warm up healthy.
    for t in 0..4 {
        for _ in 0..10 {
            mon.observe(t, 1e-3);
        }
    }
    mon.check(&mut reg);
    // One bad window for tenant 2...
    for _ in 0..10 {
        mon.observe(2, 3e-3);
    }
    mon.check(&mut reg); // strike 1
    assert_eq!(reg.get(2).unwrap().health, Health::Degraded { strikes: 1 });
    // ...then recovery.
    for _ in 0..60 {
        mon.observe(2, 1e-3);
    }
    mon.check(&mut reg);
    assert_eq!(reg.get(2).unwrap().health, Health::Healthy);
    assert_eq!(reg.evicted_count(), 0);
}

#[test]
fn mass_straggle_evicts_nobody_healthy() {
    // If EVERY tenant slows down equally (device-wide contention, not a
    // straggler), the median moves with them: nobody should be evicted.
    let mut reg = registry(6);
    let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
    for round in 0..10 {
        let lat = 1e-3 * (1.0 + round as f64); // everyone degrades together
        for t in 0..6 {
            for _ in 0..4 {
                mon.observe(t, lat);
            }
        }
        mon.check(&mut reg);
    }
    assert_eq!(reg.evicted_count(), 0, "uniform slowdown is not straggling");
}

// ---------------------------------------------------------------------------
// PJRT-path injections
// ---------------------------------------------------------------------------

fn slow_tenant_config(dir: std::path::PathBuf) -> ServerConfig {
    ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        artifacts_dir: dir,
        eviction_enabled: true,
        eviction_threshold: 1.15,
        eviction_strikes: 2,
        tenants: (0..4)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: "sgemm:64x32x48".into(),
                batch: 1,
                slo_ms: 1000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    }
}

#[test]
fn evicted_tenants_queued_requests_fail_crisply() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = slow_tenant_config(dir);
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(1);

    // Force-evict tenant 3, with requests still queued.
    let p = coord.random_payload(3, &mut rng);
    coord.submit(3, p.clone()).unwrap();
    coord.tenants.evict(3);

    // New submissions are rejected with TenantEvicted...
    assert_eq!(coord.submit(3, p.clone()), Err(Reject::TenantEvicted));
    // ...healthy tenants are unaffected.
    let p0 = coord.random_payload(0, &mut rng);
    assert!(coord.submit(0, p0).is_ok());
    let responses = coord.run_until_drained().unwrap();
    // Tenant 3's queued request still executes or drains; tenant 0 completes.
    assert!(responses.iter().any(|r| r.tenant == 0));
}

#[test]
fn injected_service_skew_triggers_runtime_eviction() {
    // Drive the monitor through the real observe/check path by reporting
    // skewed service times directly (the injection point the paper's
    // "evict degraded workers" mechanism watches).
    let Some(dir) = artifacts_dir() else { return };
    let cfg = slow_tenant_config(dir);
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(2);

    // Serve enough real traffic to give every tenant samples.
    for _ in 0..10 {
        for t in 0..4 {
            let p = coord.random_payload(t, &mut rng);
            coord.submit(t, p).unwrap();
        }
        coord.run_until_drained().unwrap();
    }
    // No eviction yet under uniform load.
    assert_eq!(coord.force_check().len(), 0);
    assert_eq!(coord.tenants.evicted_count(), 0);
}

#[test]
fn queue_overflow_rejects_and_recovers() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = slow_tenant_config(dir);
    cfg.queue_depth = 4;
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(3);
    let p = coord.random_payload(0, &mut rng);
    let mut accepted = 0;
    let mut rejected = 0;
    for _ in 0..10 {
        match coord.submit(0, p.clone()) {
            Ok(_) => accepted += 1,
            Err(Reject::QueueFull) => rejected += 1,
            Err(e) => panic!("unexpected rejection {e:?}"),
        }
    }
    assert_eq!(accepted, 4);
    assert_eq!(rejected, 6);
    coord.run_until_drained().unwrap();
    // Post-drain, capacity is restored.
    assert!(coord.submit(0, p).is_ok());
    // Rejections surfaced in metrics.
    let snap = coord.snapshot();
    assert_eq!(snap.tenants.get("t0").unwrap().rejected, 6);
}

#[test]
fn malformed_payload_cannot_poison_a_batch() {
    // A bad request is rejected at submit; it must never corrupt a fused
    // launch containing other tenants' work.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = slow_tenant_config(dir);
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(4);
    // Good requests from tenants 0-2.
    for t in 0..3 {
        let p = coord.random_payload(t, &mut rng);
        coord.submit(t, p).unwrap();
    }
    // Malformed from tenant 3.
    let bad = vec![
        stgpu::runtime::HostTensor::zeros(&[1, 1]),
        stgpu::runtime::HostTensor::zeros(&[1, 1]),
    ];
    assert!(matches!(coord.submit(3, bad), Err(Reject::BadRequest(_))));
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(responses.len(), 3, "good requests unaffected");
    assert!(
        responses.iter().all(|r| r.fused_r == 3),
        "the 3 good problems fused together (padded to bucket 4)"
    );
}

#[test]
fn coordinator_rejects_unservable_model_at_startup() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        artifacts_dir: dir,
        tenants: vec![TenantConfig {
            name: "weird".into(),
            model: "sgemm:77x33x11".into(), // never lowered
            batch: 1,
            slo_ms: 100.0,
            weight_seed: 0,
        }],
        ..Default::default()
    };
    let err = Coordinator::new(&cfg).err().expect("must fail fast");
    assert!(err.to_string().contains("no AOT artifact"), "{err:#}");
}

// ---------------------------------------------------------------------------
// Launch-failure retry via the steal path
// ---------------------------------------------------------------------------

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use stgpu::coordinator::{Flavor, LaunchExecutor, LaunchResult, WorkItem};

fn lanes2_config(dir: std::path::PathBuf) -> ServerConfig {
    ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        lanes: 2,
        artifacts_dir: dir,
        tenants: vec![
            TenantConfig {
                name: "a".into(),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: 0,
            },
            TenantConfig {
                name: "b".into(),
                model: "sgemm:256x256x256".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: 1,
            },
        ],
        ..Default::default()
    }
}

/// Fails exactly one first-attempt launch, then delegates everything —
/// the retry (attempt 1) lands on the real executor and succeeds.
struct FailFirst {
    inner: Arc<dyn LaunchExecutor>,
    fired: AtomicBool,
}

impl LaunchExecutor for FailFirst {
    fn execute(&self, item: &WorkItem) -> anyhow::Result<LaunchResult> {
        if item.attempt == 0 && !self.fired.swap(true, Ordering::SeqCst) {
            anyhow::bail!("injected launch failure");
        }
        self.inner.execute(item)
    }
}

#[test]
fn failed_launch_retries_once_on_another_lane_and_responses_survive() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = lanes2_config(dir);
    let mut coord = Coordinator::with_flavor_wrapped(&cfg, Flavor::Xla, &|inner| {
        Arc::new(FailFirst {
            inner,
            fired: AtomicBool::new(false),
        })
    })
    .unwrap();
    let mut rng = Rng::new(21);
    let mut sent = 0;
    for t in 0..2usize {
        for _ in 0..3 {
            let p = coord.random_payload(t, &mut rng);
            coord.submit(t, p).unwrap();
            sent += 1;
        }
    }
    let responses = coord.run_until_drained().unwrap();
    assert_eq!(
        responses.len(),
        sent,
        "the failed launch was re-run on another lane, so no response is lost"
    );
    let snaps = coord.device_snapshots();
    assert_eq!(snaps[0].launch_retries, 1, "exactly one retry recorded");
}

/// Fails BOTH attempts of the first work item it sees (keyed by its
/// round/index tag, so the retried copy is recognised on the other lane)
/// and delegates everything else.
struct FailTwice {
    inner: Arc<dyn LaunchExecutor>,
    target: Mutex<Option<(u64, usize)>>,
}

impl LaunchExecutor for FailTwice {
    fn execute(&self, item: &WorkItem) -> anyhow::Result<LaunchResult> {
        {
            let mut t = self.target.lock().unwrap();
            match *t {
                None => {
                    *t = Some((item.round, item.index));
                    anyhow::bail!("injected launch failure (first attempt)");
                }
                Some(k) if k == (item.round, item.index) => {
                    anyhow::bail!("injected launch failure (retry)");
                }
                _ => {}
            }
        }
        self.inner.execute(item)
    }
}

#[test]
fn second_launch_failure_drops_the_item_but_serving_continues() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = lanes2_config(dir);
    let mut coord = Coordinator::with_flavor_wrapped(&cfg, Flavor::Xla, &|inner| {
        Arc::new(FailTwice {
            inner,
            target: Mutex::new(None),
        })
    })
    .unwrap();
    let mut rng = Rng::new(22);
    let mut sent = 0;
    for t in 0..2usize {
        for _ in 0..3 {
            let p = coord.random_payload(t, &mut rng);
            coord.submit(t, p).unwrap();
            sent += 1;
        }
    }
    let responses = coord.run_until_drained().unwrap();
    assert!(
        responses.len() < sent,
        "the twice-failed launch's requests are dropped, not silently retried forever"
    );
    assert!(
        !responses.is_empty(),
        "other launches in the same rounds still complete"
    );
    assert_eq!(coord.device_snapshots()[0].launch_retries, 1);
    // The coordinator is not wedged: fresh traffic still drains.
    let p = coord.random_payload(0, &mut rng);
    coord.submit(0, p).unwrap();
    let more = coord.run_until_drained().unwrap();
    assert_eq!(more.len(), 1, "system keeps serving after the drop");
}
