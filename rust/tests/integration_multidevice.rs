//! Integration: the multi-device device pool and the bounded admission
//! front — the two halves of the sharded-coordinator change.
//!
//! * Aggregate SpaceTime throughput must increase monotonically as the
//!   pool grows 1 → 4 devices (the fig8 bench's headline curve), and beat
//!   TimeMux at every pool size.
//! * A saturated bounded queue must produce explicit `Rejected` outcomes
//!   (shed) instead of unbounded queue growth.
//!
//! Pure logic + simulator — no PJRT artifacts required.

use std::time::Instant;

use stgpu::coordinator::placement::{place, DevicePlacer};
use stgpu::coordinator::request::{InferenceRequest, Priority, Reject, ShapeClass};
use stgpu::coordinator::QueueSet;
use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::workload::sgemm_tenants;

fn pool_throughput(policy: Policy, devices: usize) -> f64 {
    // 96 conv2_2 tenants: enough backlog that every pool size stays
    // saturated (96/d tenants per device, fused in chunks of max_batch).
    let w = sgemm_tenants(96, 4, GemmShape::RESNET18_CONV2_2);
    let cfg = SimConfig::new(DeviceSpec::v100(), policy);
    gpusim::run_pool(&cfg, &w, devices).throughput_flops()
}

#[test]
fn spacetime_throughput_scales_monotonically_1_to_4_devices() {
    let mut last = 0.0;
    for d in 1..=4usize {
        let t = pool_throughput(Policy::SpaceTime { max_batch: 32 }, d);
        assert!(
            t > last,
            "aggregate SpaceTime throughput must increase with pool size: \
             {d} devices gave {t:.3e} <= {last:.3e}"
        );
        last = t;
    }
    // And the pool multiplies meaningfully: 4 devices >= 2x one device.
    let t1 = pool_throughput(Policy::SpaceTime { max_batch: 32 }, 1);
    let t4 = pool_throughput(Policy::SpaceTime { max_batch: 32 }, 4);
    assert!(t4 >= 2.0 * t1, "4-device pool {t4:.3e} vs 1-device {t1:.3e}");
}

#[test]
fn spacetime_beats_timemux_at_every_pool_size() {
    for d in 1..=4usize {
        let st = pool_throughput(Policy::SpaceTime { max_batch: 32 }, d);
        let tm = pool_throughput(Policy::TimeMux, d);
        assert!(
            st > tm,
            "devices={d}: space-time {st:.3e} must beat time-mux {tm:.3e}"
        );
    }
}

#[test]
fn pool_never_exceeds_aggregate_peak() {
    let spec = DeviceSpec::v100();
    for d in 1..=4usize {
        let t = pool_throughput(Policy::SpaceTime { max_batch: 64 }, d);
        assert!(
            t <= spec.peak_flops() * d as f64 * 1.001,
            "devices={d}: {t:.3e} exceeds aggregate peak"
        );
    }
}

#[test]
fn placement_keeps_small_classes_whole_and_spreads_dominant_ones() {
    // Mirror of the coordinator's tenant placement: four distinct shape
    // classes stay whole (fusion preserved); one dominant class spreads.
    let classes = [
        ShapeClass::batched_gemm(512, 1, 512),
        ShapeClass::batched_gemm(256, 128, 1152),
        ShapeClass::batched_gemm(256, 256, 256),
        ShapeClass::batched_gemm(64, 32, 48),
    ];
    // Equal per-tenant load: each class is exactly a fair device share, so
    // affinity keeps every class whole.
    let items: Vec<(ShapeClass, f64)> = (0..16).map(|i| (classes[i % 4], 1.0)).collect();
    let p = place(&items, 4);
    for c in classes {
        let devices: std::collections::BTreeSet<usize> = items
            .iter()
            .enumerate()
            .filter(|(_, (k, _))| *k == c)
            .map(|(i, _)| p.device_of(i))
            .collect();
        assert_eq!(devices.len(), 1, "class {c} split across shards");
    }
    // One dominant class on its own must still use the whole pool.
    let dominant: Vec<(ShapeClass, f64)> =
        (0..32).map(|_| (classes[1], classes[1].flops())).collect();
    let p2 = place(&dominant, 4);
    for d in 0..4 {
        assert_eq!(p2.members(d).len(), 8, "device {d} share of dominant class");
    }
}

fn req(id: u64, tenant: usize) -> InferenceRequest {
    InferenceRequest {
        id,
        tenant,
        class: ShapeClass::batched_gemm(64, 64, 64),
        payload: vec![],
        arrived: Instant::now(),
        deadline: Instant::now(),
        priority: Priority::Normal,
        trace_id: 0,
    }
}

#[test]
fn saturated_bounded_queue_sheds_instead_of_growing() {
    // The acceptance-criterion test: drive 50x the global cap into the
    // admission front. Pending must stay bounded by the cap at every step,
    // the overflow must surface as explicit Rejected outcomes, and the
    // counters must tie out exactly — nothing silently dropped or queued.
    const CAP: usize = 32;
    let mut qs = QueueSet::with_global_cap(8, 16, CAP);
    let mut admitted = 0u64;
    let mut shed = 0u64;
    let mut tenant_full = 0u64;
    for i in 0..(50 * CAP as u64) {
        match qs.push(req(i, (i % 8) as usize)) {
            Ok(()) => admitted += 1,
            Err(Reject::Overloaded) => shed += 1,
            Err(Reject::QueueFull) => tenant_full += 1,
            Err(e) => panic!("unexpected outcome {e:?}"),
        }
        assert!(
            qs.total_pending() <= CAP,
            "queue grew past the cap at step {i}"
        );
    }
    assert_eq!(admitted, CAP as u64, "admission stops exactly at the cap");
    assert_eq!(admitted + shed + tenant_full, 50 * CAP as u64);
    assert!(shed > 0, "saturation must surface as explicit shed outcomes");
    assert_eq!(qs.shed, shed, "shed counter matches observed outcomes");

    // Draining restores exactly the freed capacity — the front recovers.
    for _ in 0..10 {
        let t = qs.backlogged()[0];
        assert!(qs.pop_tenant(t).is_some());
    }
    let mut readmitted = 0;
    for i in 0..20u64 {
        if qs.push(req(10_000 + i, (i % 8) as usize)).is_ok() {
            readmitted += 1;
        }
    }
    assert_eq!(readmitted, 10);
    assert_eq!(qs.total_pending(), CAP);
}

#[test]
fn shed_outcome_is_429_style() {
    assert_eq!(Reject::Overloaded.http_status(), 429);
    assert_eq!(Reject::QueueFull.http_status(), 429);
}

#[test]
fn eviction_and_readmission_keep_placer_accounting_and_affinity_consistent() {
    // Mirror of the coordinator's tenant placement across 2 devices: two
    // shape classes, two tenants each, per-tenant load = per-request FLOPs.
    // Equal-FLOP classes (2·128·128·1024 == 2·256³) so each class is
    // exactly a fair device share and placement keeps both whole.
    let classes = [
        ShapeClass::batched_gemm(128, 128, 1024),
        ShapeClass::batched_gemm(256, 256, 256),
    ];
    let items: Vec<(ShapeClass, f64)> = (0..4)
        .map(|i| {
            let c = classes[i / 2];
            (c, c.flops())
        })
        .collect();
    let mut placer = DevicePlacer::new(&items, 2);
    let total: f64 = items.iter().map(|(_, l)| l).sum();
    let load_sum = |p: &DevicePlacer<ShapeClass>| -> f64 {
        p.placement().load.iter().sum()
    };
    assert!((load_sum(&placer) - total).abs() < 1e-6);
    // Classes placed whole: each tenant shares a device with its peer.
    assert_eq!(placer.device_of(0), placer.device_of(1));
    assert_eq!(placer.device_of(2), placer.device_of(3));
    let home = placer.device_of(1);

    // Evict tenant 1: its load leaves the shard, everyone else's stays.
    placer.release(1);
    assert!(!placer.is_active(1));
    assert!((load_sum(&placer) - placer.active_load()).abs() < 1e-6);
    assert!((load_sum(&placer) - (total - items[1].1)).abs() < 1e-6);
    // Double-release is a no-op (the monitor can only evict once, but the
    // accounting must not depend on that).
    placer.release(1);
    assert!((load_sum(&placer) - (total - items[1].1)).abs() < 1e-6);

    // Re-register the tenant: it must re-join its shape class's device
    // (fusion affinity survives the eviction round trip) and the load
    // books must balance exactly again.
    let d = placer.readmit(1);
    assert_eq!(d, home, "re-admitted tenant re-joins its class's shard");
    assert_eq!(d, placer.device_of(0), "co-located with its class peer");
    assert!(placer.is_active(1));
    assert!((load_sum(&placer) - total).abs() < 1e-6);
    assert!((load_sum(&placer) - placer.active_load()).abs() < 1e-6);

    // If the WHOLE class was evicted, re-admission falls back to the
    // least-loaded shard instead of chasing ghosts.
    placer.release(0);
    placer.release(1);
    let d0 = placer.readmit(0);
    assert_eq!(
        d0, home,
        "first member back lands on the now-emptiest shard (its old home)"
    );
    let d1 = placer.readmit(1);
    assert_eq!(d1, d0, "second member re-joins the first: affinity restored");
    assert!((load_sum(&placer) - total).abs() < 1e-6);
}
