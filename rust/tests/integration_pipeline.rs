//! Integration: the pipelined persistent-lane driver over real PJRT
//! artifacts — `pipeline_depth = 1` reproduces the serial driver's
//! results bit-for-bit, `pipeline_depth = 2` loses no completions across
//! drain/shutdown, the round hot path stops allocating after warmup
//! (arena growth counter), and snapshots never touch the cost-model lock.
//!
//! Requires `make artifacts` (skips with a message otherwise). The
//! artifact-free halves of these properties are unit-tested in
//! `coordinator::lanepool` (round tagging, zero-lost-completions
//! shutdown) and `coordinator::driver` (arena counter, snapshot mirror).

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::Coordinator;
use stgpu::runtime::HostTensor;
use stgpu::util::prng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn config(pipeline_depth: usize, lanes: usize, n_tenants: usize) -> Option<ServerConfig> {
    let dir = artifacts_dir()?;
    Some(ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        pipeline_depth,
        lanes,
        artifacts_dir: dir,
        tenants: (0..n_tenants)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    })
}

/// Run `waves` submit/drain waves with a fixed payload seed; returns
/// responses sorted by request id as (id, tenant, fused_r, output).
fn run_waves(
    coord: &mut Coordinator,
    waves: usize,
    per_tenant: usize,
) -> Vec<(u64, usize, usize, HostTensor)> {
    let n = coord.tenants.len();
    let mut rng = Rng::new(0x9A9A);
    let mut out = Vec::new();
    for _ in 0..waves {
        for t in 0..n {
            for _ in 0..per_tenant {
                let payload = coord.random_payload(t, &mut rng);
                coord.submit(t, payload).unwrap();
            }
        }
        for r in coord.run_until_drained().unwrap() {
            out.push((r.id, r.tenant, r.fused_r, r.output));
        }
    }
    out.sort_by_key(|(id, ..)| *id);
    out
}

#[test]
fn depth1_reproduces_serial_results_bit_for_bit() {
    let Some(cfg1) = config(1, 1, 4) else { return };
    let cfg2 = ServerConfig { pipeline_depth: 2, ..cfg1.clone() };
    let mut serial = Coordinator::new(&cfg1).unwrap();
    let mut pipelined = Coordinator::new(&cfg2).unwrap();
    assert_eq!(serial.pipeline_depth(), 1);
    assert_eq!(pipelined.pipeline_depth(), 2);
    let rs = run_waves(&mut serial, 3, 2);
    let rp = run_waves(&mut pipelined, 3, 2);
    assert_eq!(rs.len(), rp.len(), "same request set must fully complete");
    for ((id_s, t_s, f_s, out_s), (id_p, t_p, f_p, out_p)) in rs.iter().zip(&rp) {
        assert_eq!(id_s, id_p);
        assert_eq!(t_s, t_p);
        assert_eq!(f_s, f_p, "request {id_s}: same fused launch width");
        assert_eq!(out_s, out_p, "request {id_s}: outputs must be bit-identical");
    }
    // Same plans on both sides: launch/drain accounting matches exactly.
    let (ds, dp) = (serial.device_snapshots(), pipelined.device_snapshots());
    assert_eq!(ds[0].launches, dp[0].launches);
    assert_eq!(ds[0].drained, dp[0].drained);
    assert_eq!(ds[0].superkernel_launches, dp[0].superkernel_launches);
}

#[test]
fn pipelined_multilane_drain_loses_no_completions() {
    // Two shape classes across 4 tenants, 2 lanes, depth 2: rounds
    // overlap on the persistent workers, yet every submission completes
    // exactly once and the per-lane accounting ties out.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        pipeline_depth: 2,
        lanes: 2,
        artifacts_dir: dir,
        tenants: (0..4)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: if i % 2 == 0 {
                    "sgemm:256x128x1152".into()
                } else {
                    "sgemm:256x256x256".into()
                },
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(7);
    let mut submitted = 0u64;
    for _ in 0..6 {
        for t in 0..4usize {
            for _ in 0..2 {
                let payload = coord.random_payload(t, &mut rng);
                coord.submit(t, payload).unwrap();
                submitted += 1;
            }
        }
        let responses = coord.run_until_drained().unwrap();
        assert!(!responses.is_empty());
    }
    assert_eq!(coord.in_flight_rounds(), 0, "drain must collect every round");
    let snap = coord.device_snapshots();
    let completed: u64 = coord
        .snapshot()
        .tenants
        .values()
        .map(|t| t.completed)
        .sum();
    assert_eq!(completed, submitted, "zero lost completions");
    let lane_total: u64 = snap[0].lane_launches.iter().sum();
    assert_eq!(lane_total, snap[0].launches, "per-lane accounting ties out");
}

#[test]
fn round_hot_path_stops_allocating_after_warmup() {
    // The acceptance claim: after warmup, steady identical rounds must
    // not grow the arena (launch/lane vectors recycled across rounds).
    let Some(cfg) = config(2, 1, 4) else { return };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(3);
    let wave = |coord: &mut Coordinator, rng: &mut Rng| {
        for t in 0..4usize {
            let payload = coord.random_payload(t, rng);
            coord.submit(t, payload).unwrap();
        }
        coord.run_until_drained().unwrap();
    };
    for _ in 0..4 {
        wave(&mut coord, &mut rng); // warmup
    }
    let warmed = coord.arena_grows();
    for _ in 0..16 {
        wave(&mut coord, &mut rng);
    }
    assert_eq!(
        coord.arena_grows(),
        warmed,
        "steady-state rounds must perform zero arena growths"
    );
}

#[test]
fn snapshot_never_blocks_on_the_cost_model() {
    // Regression for the snapshot-path contention bug: hold the shard's
    // cost-model lock (as an in-flight planning/feedback step would) and
    // take a snapshot — the mirror-backed path must complete. Before the
    // fix, device_snapshots() locked the cost model and this deadlocked.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        edf: true,
        pipeline_depth: 2,
        artifacts_dir: dir,
        tenants: (0..2)
            .map(|i| TenantConfig {
                name: format!("t{i}"),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 10_000.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg).unwrap();
    let mut rng = Rng::new(11);
    for t in 0..2usize {
        let payload = coord.random_payload(t, &mut rng);
        coord.submit(t, payload).unwrap();
    }
    coord.run_until_drained().unwrap();
    let cm = coord.cost_model(0).expect("EDF shard has a cost model").clone();
    let guard = cm.lock().unwrap();
    let snaps = coord.device_snapshots();
    assert_eq!(snaps.len(), 1);
    assert!(snaps[0].launches > 0);
    assert!(snaps[0].cost_calibration_error >= 0.0);
    drop(guard);
}
