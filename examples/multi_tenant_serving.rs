//! END-TO-END driver (the repo's headline experiment, recorded in
//! EXPERIMENTS.md): serve batched inference for N tenants through the REAL
//! PJRT path under all four schedulers, reporting p50/p99 latency and
//! throughput — plus the V100 simulator's projection of the same contest
//! next to it.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example multi_tenant_serving
//! ```
//!
//! Workload: 8 tenants, each a two-layer MLP block with its own weights
//! (paper §2: same architecture, different weights), closed-loop clients
//! keeping 8 requests in flight each (saturated queues).

use std::time::{Duration, Instant};

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::Coordinator;
use stgpu::gpusim::{self, DeviceSpec, GemmShape, Policy, SimConfig};
use stgpu::server::{ServeOpts, Server};
use stgpu::util::bench::{fmt_flops, fmt_secs, Table};
use stgpu::util::prng::Rng;
use stgpu::workload::sgemm_tenants;

const TENANTS: usize = 8;
const DEPTH: usize = 8;
const DURATION: Duration = Duration::from_secs(3);

fn config(scheduler: SchedulerKind) -> ServerConfig {
    ServerConfig {
        scheduler,
        max_batch: 64,
        batch_timeout_us: 200,
        artifacts_dir: "artifacts".into(),
        tenants: (0..TENANTS)
            .map(|i| TenantConfig {
                name: format!("tenant{i}"),
                model: "mlp".into(),
                batch: 1,
                slo_ms: 250.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    }
}

struct RunResult {
    scheduler: &'static str,
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    superkernels: u64,
    singletons: u64,
    fusion_hit_rate: f64,
}

fn serve_one(kind: SchedulerKind) -> anyhow::Result<RunResult> {
    let cfg = config(kind);
    let coord = Coordinator::new(&cfg)?;
    coord.warmup()?;
    let label = coord.scheduler_label();
    let server = Server::start(
        coord,
        ServeOpts {
            batch_timeout: Duration::from_micros(cfg.batch_timeout_us),
            ..Default::default()
        },
    );
    let stop_at = Instant::now() + DURATION;
    let mut clients = Vec::new();
    for t in 0..TENANTS {
        let h = server.handle();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xE2E + t as u64);
            let mut ok = 0u64;
            while Instant::now() < stop_at {
                let pending: Vec<_> = (0..DEPTH)
                    .map(|_| {
                        h.submit(t, vec![stgpu::runtime::HostTensor::random(&[8, 256], &mut rng)])
                    })
                    .collect();
                for rx in pending {
                    if matches!(rx.recv(), Ok(Ok(_))) {
                        ok += 1;
                    }
                }
            }
            ok
        }));
    }
    for c in clients {
        c.join().expect("client");
    }
    let coord = server.shutdown();
    let snap = coord.snapshot();
    let mut p50s = Vec::new();
    let mut p99s = Vec::new();
    for t in snap.tenants.values() {
        if t.completed > 0 {
            p50s.push(t.latency_p50_ns as f64 / 1e6);
            p99s.push(t.latency_p99_ns as f64 / 1e6);
        }
    }
    p50s.sort_by(f64::total_cmp);
    p99s.sort_by(f64::total_cmp);
    Ok(RunResult {
        scheduler: label,
        rps: snap.throughput_rps(),
        p50_ms: stgpu::util::stats::percentile(&p50s, 50.0),
        p99_ms: p99s.last().copied().unwrap_or(0.0),
        superkernels: snap.superkernel_launches,
        singletons: snap.kernel_launches,
        fusion_hit_rate: coord.fusion_cache_stats().hit_rate(),
    })
}

fn main() -> anyhow::Result<()> {
    println!("== multi-tenant serving: {TENANTS} MLP tenants, depth {DEPTH}, {:?} per scheduler ==\n", DURATION);

    // --- The real PJRT serving contest -----------------------------------
    let mut table = Table::new(&[
        "scheduler", "req/s", "p50_ms", "worst_p99_ms", "superkernels", "singletons", "fusion_hit_%",
    ]);
    let mut best_st_rps = 0.0;
    let mut tm_rps = 0.0;
    for kind in [
        SchedulerKind::Exclusive,
        SchedulerKind::TimeMux,
        SchedulerKind::SpaceMux,
        SchedulerKind::SpaceTime,
    ] {
        let r = serve_one(kind)?;
        if kind == SchedulerKind::SpaceTime {
            best_st_rps = r.rps;
        }
        if kind == SchedulerKind::TimeMux {
            tm_rps = r.rps;
        }
        table.row(&[
            r.scheduler.to_string(),
            format!("{:.0}", r.rps),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p99_ms),
            r.superkernels.to_string(),
            r.singletons.to_string(),
            format!("{:.0}", r.fusion_hit_rate * 100.0),
        ]);
    }
    println!("{}", table.render());
    println!(
        "headline (real PJRT-CPU path): space-time {:.0} req/s vs time-mux {:.0} req/s \
         ({:.2}x)\n",
        best_st_rps,
        tm_rps,
        best_st_rps / tm_rps.max(1e-9)
    );

    // --- The V100-scaled projection of the same contest ------------------
    println!("V100 simulator projection (conv2_2 SGEMM per request, {TENANTS} tenants):");
    let mut sim = Table::new(&["policy", "throughput", "mean_latency"]);
    for policy in [
        Policy::Exclusive,
        Policy::TimeMux,
        Policy::SpaceMuxMps { anomaly_seed: 3 },
        Policy::SpaceTime { max_batch: 64 },
    ] {
        let cfg = SimConfig::new(DeviceSpec::v100(), policy);
        let report = gpusim::run(
            &cfg,
            &sgemm_tenants(TENANTS, 50, GemmShape::RESNET18_CONV2_2),
        );
        sim.row(&[
            cfg.policy.label().to_string(),
            fmt_flops(report.throughput_flops()),
            fmt_secs(report.mean_latency()),
        ]);
    }
    println!("{}", sim.render());
    println!(
        "Recorded in EXPERIMENTS.md — the CPU path demonstrates the real\n\
         mechanism (one fused launch, cached device-resident weights); the\n\
         simulator scales the shape to the paper's V100 testbed."
    );
    Ok(())
}
