//! Scenario: open-loop bursty traffic replayed against the live server —
//! the "key focus of future work" the paper names in §2 (queuing latency
//! under stochastic arrivals), exercised on the real PJRT path.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example trace_replay
//! ```
//!
//! Six tenants with heterogeneous Poisson/bursty arrival processes are
//! merged into one timestamped trace; a replay thread fires each request
//! at its scheduled instant; we compare queueing + service latency under
//! space-time vs time-only scheduling at the same offered load.

use std::time::{Duration, Instant};

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::Coordinator;
use stgpu::server::{ServeOpts, Server};
use stgpu::util::bench::Table;
use stgpu::util::prng::Rng;
use stgpu::workload::{ArrivalProcess, RequestTrace};

const TENANTS: usize = 6;
const HORIZON_S: f64 = 3.0;

fn main() -> anyhow::Result<()> {
    // 1. Build the multi-tenant trace: mixed steady + bursty arrivals.
    let processes: Vec<(usize, ArrivalProcess)> = (0..TENANTS)
        .map(|t| {
            let p = if t % 3 == 2 {
                ArrivalProcess::Bursty { low: 20.0, high: 120.0, dwell: 0.4 }
            } else {
                ArrivalProcess::Poisson { rate: 40.0 + 10.0 * t as f64 }
            };
            (t, p)
        })
        .collect();
    let trace = RequestTrace::generate(&processes, 0xACE, HORIZON_S);
    let offered: f64 = trace.len() as f64 / HORIZON_S;
    println!(
        "trace: {} requests over {HORIZON_S} s ({offered:.0} req/s offered, {} tenants)\n",
        trace.len(),
        TENANTS
    );

    // 2. Replay under both schedulers.
    let mut table = Table::new(&[
        "scheduler", "served", "dropped", "p50_ms", "p99_ms", "superkernels",
    ]);
    for kind in [SchedulerKind::TimeMux, SchedulerKind::SpaceTime] {
        let row = replay(&trace, kind)?;
        table.row(&row);
    }
    println!("{}", table.render());
    println!(
        "shape check: at the same offered load, space-time absorbs the\n\
         bursts — fused launches drain the backlog in one pass, cutting\n\
         worst-tenant p99 by ~5x vs the serialized time-mux baseline at\n\
         comparable completion counts (this host is 1-core, so the fused\n\
         launch gains no parallel speedup — on the paper's V100 it gains\n\
         both). The paper's named future-work scenario, handled."
    );
    Ok(())
}

fn replay(trace: &RequestTrace, kind: SchedulerKind) -> anyhow::Result<[String; 6]> {
    let cfg = ServerConfig {
        scheduler: kind,
        max_batch: 64,
        // This substrate runs lanes serially (1-core CPU-PJRT), so padded
        // lanes cost real compute: use the zero-padding binary-split
        // batching mode (see PaddingPolicy::SplitExact).
        split_exact: true,
        batch_timeout_us: 500,
        queue_depth: 128,
        artifacts_dir: "artifacts".into(),
        tenants: (0..TENANTS)
            .map(|i| TenantConfig {
                name: format!("svc{i}"),
                model: "mlp".into(),
                batch: 1,
                slo_ms: 100.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    };
    let coord = Coordinator::new(&cfg)?;
    coord.warmup()?;
    let label = coord.scheduler_label();
    let server = Server::start(
        coord,
        ServeOpts {
            batch_timeout: Duration::from_micros(cfg.batch_timeout_us),
            ..Default::default()
        },
    );

    // Replay thread: fire each request at its trace timestamp; a collector
    // drains replies without blocking the timeline.
    let h = server.handle();
    let t0 = Instant::now();
    let mut rng = Rng::new(1);
    let mut receivers = Vec::with_capacity(trace.len());
    for req in &trace.requests {
        let due = Duration::from_secs_f64(req.t_arrival);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let payload = vec![stgpu::runtime::HostTensor::random(&[8, 256], &mut rng)];
        receivers.push(h.submit(req.tenant, payload));
    }
    let mut served = 0u64;
    let mut dropped = 0u64;
    for rx in receivers {
        match rx.recv_timeout(Duration::from_secs(20)) {
            Ok(Ok(_)) => served += 1,
            _ => dropped += 1,
        }
    }
    let coord = server.shutdown();
    let snap = coord.snapshot();
    let mut p50s: Vec<f64> = snap
        .tenants
        .values()
        .filter(|t| t.completed > 0)
        .map(|t| t.latency_p50_ns as f64 / 1e6)
        .collect();
    p50s.sort_by(f64::total_cmp);
    let worst_p99 = snap
        .tenants
        .values()
        .map(|t| t.latency_p99_ns as f64 / 1e6)
        .fold(0.0, f64::max);
    Ok([
        label.to_string(),
        served.to_string(),
        dropped.to_string(),
        format!("{:.2}", stgpu::util::stats::percentile(&p50s, 50.0)),
        format!("{worst_p99:.2}"),
        snap.superkernel_launches.to_string(),
    ])
}
