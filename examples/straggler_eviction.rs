//! Scenario: latency predictability under an injected straggler — the
//! paper's §4 isolation mechanism, live.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example straggler_eviction
//! ```
//!
//! Eight RNN-cell tenants share the device under space-time scheduling.
//! We inject an MPS-style scheduling anomaly against one tenant by feeding
//! the SLO monitor a skewed latency stream, watch it accumulate strikes,
//! get evicted, and verify the survivors' latency spread collapses while
//! total throughput barely moves.

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::{Coordinator, Health};
use stgpu::util::bench::Table;
use stgpu::util::prng::Rng;

const TENANTS: usize = 8;
const STRAGGLER: usize = 5;

fn main() -> anyhow::Result<()> {
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        eviction_enabled: true,
        eviction_threshold: 1.15,
        eviction_strikes: 3,
        artifacts_dir: "artifacts".into(),
        tenants: (0..TENANTS)
            .map(|i| TenantConfig {
                name: format!("rnn{i}"),
                model: "rnn_cell".into(),
                batch: 1,
                slo_ms: 100.0,
                weight_seed: i as u64,
            })
            .collect(),
        ..Default::default()
    };
    let mut coord = Coordinator::new(&cfg)?;
    coord.warmup()?;
    let mut rng = Rng::new(99);

    println!("== phase 1: healthy steady state ==");
    serve_rounds(&mut coord, &mut rng, 6, None);
    report(&coord);

    println!("\n== phase 2: inject a 1.3x anomaly against tenant {STRAGGLER} ==");
    // The injection point is the monitor's observation stream — exactly
    // where a real MPS anomaly would surface (paper Figure 4).
    let mut evicted_round = None;
    for round in 0..12 {
        serve_rounds(&mut coord, &mut rng, 1, Some(STRAGGLER));
        let evs = coord.force_check();
        if !evs.is_empty() {
            evicted_round = Some(round);
            println!(
                "round {round}: tenant {} evicted (EWMA {:.2}x the median)",
                evs[0].tenant, evs[0].ratio
            );
            break;
        }
        let health = coord
            .tenants
            .get(STRAGGLER)
            .map(|t| t.health)
            .unwrap_or(Health::Healthy);
        println!("round {round}: straggler health = {health:?}");
    }
    assert_eq!(
        coord.tenants.get(STRAGGLER).unwrap().health,
        Health::Evicted,
        "the straggler must be evicted"
    );
    assert_eq!(coord.tenants.evicted_count(), 1, "ONLY the straggler");

    println!("\n== phase 3: post-eviction steady state ==");
    serve_rounds(&mut coord, &mut rng, 6, None);
    report(&coord);

    let snap = coord.snapshot();
    println!(
        "\nsummary: evicted after {} injected rounds; {} of {TENANTS} tenants \
         still serving; {} total completions.",
        evicted_round.map(|r| r + 1).unwrap_or(0),
        coord.tenants.servable().count(),
        snap.total_completed(),
    );
    println!(
        "paper §4: \"we can simply evict degraded workers without \
         significantly impacting total system throughput.\""
    );
    Ok(())
}

/// Serve `rounds` of one request per servable tenant; optionally skew the
/// monitor's view of one tenant (the anomaly injection).
fn serve_rounds(
    coord: &mut Coordinator,
    rng: &mut Rng,
    rounds: usize,
    skew_tenant: Option<usize>,
) {
    for _ in 0..rounds {
        for t in 0..TENANTS {
            if coord.tenants.get(t).map_or(false, |x| x.is_servable()) {
                let p = coord.random_payload(t, rng);
                coord.submit(t, p).unwrap();
            }
        }
        let responses = coord.run_until_drained().unwrap();
        if let Some(victim) = skew_tenant {
            // Re-observe the victim's completions 30% slow: the anomaly.
            for r in responses.iter().filter(|r| r.tenant == victim) {
                for _ in 0..3 {
                    coord.monitor_observe(victim, r.service_s * 1.3);
                }
            }
        }
    }
}

fn report(coord: &Coordinator) {
    let snap = coord.snapshot();
    let mut table = Table::new(&["tenant", "health", "completed", "p50_us", "p99_us"]);
    for t in coord.tenants.iter() {
        let m = snap.tenants.get(&t.name);
        table.row(&[
            t.name.clone(),
            format!("{:?}", t.health),
            m.map(|x| x.completed.to_string()).unwrap_or_default(),
            m.map(|x| format!("{:.0}", x.latency_p50_ns as f64 / 1e3))
                .unwrap_or_default(),
            m.map(|x| format!("{:.0}", x.latency_p99_ns as f64 / 1e3))
                .unwrap_or_default(),
        ]);
    }
    println!("{}", table.render());
}
