//! Quickstart: deploy two tenants, submit a few requests, read the results.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```
//!
//! Walks the whole public API surface in ~60 lines: config → coordinator →
//! submit → space-time round → responses → metrics snapshot.

use stgpu::config::{SchedulerKind, ServerConfig, TenantConfig};
use stgpu::coordinator::Coordinator;
use stgpu::runtime::HostTensor;
use stgpu::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Two tenants sharing one device: same architecture, different
    //    weights (paper §2's application model).
    let cfg = ServerConfig {
        scheduler: SchedulerKind::SpaceTime,
        artifacts_dir: "artifacts".into(),
        tenants: vec![
            TenantConfig {
                name: "alice".into(),
                model: "mlp".into(),
                batch: 1,
                slo_ms: 100.0,
                weight_seed: 1,
            },
            TenantConfig {
                name: "bob".into(),
                model: "mlp".into(),
                batch: 1,
                slo_ms: 100.0,
                weight_seed: 2,
            },
        ],
        ..Default::default()
    };

    // 2. Build the coordinator. This loads the AOT manifest (HLO text
    //    lowered once by python/compile/aot.py — python never runs here)
    //    and pre-compiles the executables the tenants can hit.
    let mut coord = Coordinator::new(&cfg)?;
    let warmed = coord.warmup()?;
    println!(
        "coordinator up: scheduler={}, platform={}, {warmed} executables warm",
        coord.scheduler_label(),
        coord.engine().platform()
    );

    // 3. Submit one request per tenant — the same input x for both, so we
    //    can see per-tenant weights at work.
    let mut rng = Rng::new(0);
    let x = HostTensor::random(&[8, 256], &mut rng);
    let id_a = coord.submit(0, vec![x.clone()]).expect("submit alice");
    let id_b = coord.submit(1, vec![x]).expect("submit bob");

    // 4. One scheduling round: both problems fuse into ONE super-kernel
    //    launch (the paper's space-time mechanism).
    let responses = coord.run_until_drained()?;
    for r in &responses {
        println!(
            "request {} (tenant {}): output {:?}, fused with {} problems, \
             service {:.3} ms",
            r.id,
            r.tenant,
            r.output.shape,
            r.fused_r,
            r.service_s * 1e3
        );
    }
    let (a, b) = (
        responses.iter().find(|r| r.id == id_a).unwrap(),
        responses.iter().find(|r| r.id == id_b).unwrap(),
    );
    assert_eq!(a.fused_r, 2, "both tenants shared one launch");
    assert!(
        a.output.max_abs_diff(&b.output) > 1e-3,
        "different weights -> different outputs, same launch"
    );

    // 5. Metrics.
    let snap = coord.snapshot();
    println!(
        "done: {} completed, {} super-kernel launches, fusion-cache {:?}",
        snap.total_completed(),
        snap.superkernel_launches,
        coord.fusion_cache_stats()
    );
    Ok(())
}
